"""Fault-injection and scheduling suite for the continuous-batching
SortServer (EXPERIMENTS.md §Serving).

The robustness claims are proven the same way the relaxation claims
are: deterministically.  ``FaultInjector`` perturbs exact dispatch
indices (0-based call order), so every test knows precisely which
device calls failed or straggled, and the assertions are exact —
every submitted future resolves exactly once (result or typed
rejection, never a hang), retried requests resume from their last
committed round boundary bit-identically, backpressure rejects at
``submit()`` instead of deadlocking, and ``close()`` under in-flight
load strands nothing.

Deterministic scheduler tests drive ``server._tick()`` manually with
``autostart=False`` — one admission + dispatch pass per call, no
worker-thread timing in the loop.
"""
from __future__ import annotations

import time

import numpy as np
import pytest

import jax

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    run_round_segment,
    shuffle_soft_sort,
)
from repro.launch.mesh import make_sort_mesh
from repro.launch.serve import (
    DeadlineExceeded,
    QueueFull,
    RequestFailed,
    RequestRejected,
    ServerClosed,
    SortServer,
)
from repro.runtime.fault_tolerance import (
    FaultInjector,
    RetryPolicy,
    WorkerFailure,
)
from repro.runtime.straggler import StragglerMonitor

N, HW, D = 16, (4, 4), 2
CFG = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)


def _problems(count, d=D, n=N, seed=0):
    rng = np.random.RandomState(seed)
    return rng.rand(count, n, d).astype(np.float32)


def _drain(server, max_ticks=64):
    """Drive manual ticks until the server goes idle."""
    for _ in range(max_ticks):
        with server._cv:
            idle = not server._pending and not server._active
        if idle:
            return
        server._tick()
    raise AssertionError("server did not drain")


def _resolution_is_exactly_once(server, futs):
    """Every future is done, and the stats ledger accounts for each
    request exactly once across the terminal counters."""
    assert all(f.done() for f in futs)
    terminal = (server.stats["completed"] + server.stats["failed"]
                + server.stats["deadline_missed"])
    assert terminal == len(futs), server.stats


# ------------------------------------------------- retry/injector units

def test_retry_policy_backoff_schedule():
    rp = RetryPolicy(max_retries=3, backoff_base_s=0.05,
                     backoff_mult=2.0, backoff_max_s=0.15)
    assert rp.backoff(1) == 0.05
    assert rp.backoff(2) == 0.10
    assert rp.backoff(3) == 0.15           # capped
    assert rp.backoff(9) == 0.15
    with pytest.raises(ValueError):
        rp.backoff(0)


def test_fault_injector_is_deterministic():
    calls = []
    slept = []
    inj = FaultInjector(lambda v: calls.append(v) or v * 2,
                        fail_calls={1, 3}, delay_calls={0: 0.25, 1: 0.5},
                        sleep_fn=slept.append)
    assert inj(5) == 10                    # call 0: delayed, succeeds
    with pytest.raises(WorkerFailure):
        inj(6)                             # call 1: delayed AND fails
    assert inj(7) == 14                    # call 2: clean
    with pytest.raises(WorkerFailure):
        inj(8)                             # call 3: fails
    assert (inj.calls, inj.faults, inj.delays) == (4, 2, 2)
    assert slept == [0.25, 0.5]
    assert calls == [5, 7]                 # engine never saw failed calls


# ---------------------------------------------- continuous batching core

def test_mixed_progress_requests_share_one_dispatch_bit_identically():
    """The tentpole semantics: a request that joins mid-traffic batches
    with one already mid-anneal (different tau positions in the SAME
    device call) and both finish bit-identical to sequential runs."""
    xs = _problems(2)
    keys = [jax.random.PRNGKey(11), jax.random.PRNGKey(12)]
    server = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False)
    f0 = server.submit(xs[0], key=keys[0])
    server._tick()                         # r0 runs rung 1 alone
    f1 = server.submit(xs[1], key=keys[1])
    _drain(server)                         # r0+r1 share ragged dispatches
    server.close()
    assert 2 in server.stats["batch_sizes"]    # mixed-progress batch ran
    for f, x, k in ((f0, xs[0], keys[0]), (f1, xs[1], keys[1])):
        order, srt, losses = f.result(timeout=0)
        o_ref, s_ref, l_ref = shuffle_soft_sort(x, HW, CFG, key=k)
        np.testing.assert_array_equal(order, o_ref)
        np.testing.assert_array_equal(srt, np.asarray(s_ref))
        np.testing.assert_array_equal(losses, np.asarray(l_ref))
    # pad-to-bucket compile cache: 1-wide and 2-wide buckets only
    buckets = {key[3] for key in server.stats["compile_keys"]}
    assert buckets == {1, 2}


def test_priority_admission_order():
    server = SortServer(HW, d=D, cfg=CFG, max_batch=1, max_active=1,
                        autostart=False)
    xs = _problems(2)
    f_low = server.submit(xs[0], key=jax.random.PRNGKey(0), priority=0)
    f_high = server.submit(xs[1], key=jax.random.PRNGKey(1), priority=5)
    server._tick()
    admits = [e["seq"] for e in server.events if e["event"] == "admit"]
    assert admits == [1]                   # high priority jumped the queue
    _drain(server)
    server.close()
    assert f_high.result(timeout=0) and f_low.result(timeout=0)


def test_mixed_shape_traffic_batches_per_bucket():
    """Different (N, d) signatures coexist: each batches in its own
    shape bucket, results stay bit-identical to sequential runs."""
    cfg = CFG
    xa = _problems(1, d=2, n=16)[0]
    xb = _problems(1, d=3, n=8, seed=3)[0]
    ka, kb = jax.random.PRNGKey(21), jax.random.PRNGKey(22)
    server = SortServer(HW, d=D, cfg=cfg, max_batch=4, autostart=False)
    fa = server.submit(xa, key=ka)
    fb = server.submit(xb, key=kb, hw=(2, 4))
    _drain(server)
    server.close()
    oa, _, _ = fa.result(timeout=0)
    ob, _, _ = fb.result(timeout=0)
    np.testing.assert_array_equal(
        oa, shuffle_soft_sort(xa, HW, cfg, key=ka)[0])
    np.testing.assert_array_equal(
        ob, shuffle_soft_sort(xb, (2, 4), cfg, key=kb)[0])
    sigs = {(key[0], key[1]) for key in server.stats["compile_keys"]}
    assert sigs == {((4, 4), 2), ((2, 4), 3)}


def test_submit_validates_shapes():
    server = SortServer(HW, d=D, cfg=CFG, autostart=False)
    with pytest.raises(ValueError):
        server.submit(np.zeros((8, D), np.float32))      # wrong N
    with pytest.raises(ValueError):
        server.submit(np.zeros((N, 5), np.float32))      # wrong d
    with pytest.raises(ValueError):
        server.submit(np.zeros((N, D), np.float32), hw=(3, 4))
    server.close()


def test_sched_rung_alignment_is_validated():
    with pytest.raises(ValueError):
        SortServer(HW, d=D, cfg=CFG, sched_rungs=3, autostart=False)
    with pytest.raises(ValueError):                      # 4 % 3 != 0
        SortServer(HW, d=D, cfg=CFG, n_restarts=4, tournament_rungs=3,
                   autostart=False)


# ----------------------------------------------------- fault injection

def test_injected_failures_recover_every_future():
    """The archetype headline: with deterministic worker failures and a
    bounded retry budget, every future resolves exactly once — with the
    CORRECT result, because retries resume from the last committed
    boundary and recommit the same PRNG stream."""
    xs = _problems(3)
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    inj = FaultInjector(run_round_segment, fail_calls={0, 2})
    server = SortServer(HW, d=D, cfg=CFG, max_batch=4, max_wait_ms=20.0,
                        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0),
                        engine_fn=inj)
    futs = [server.submit(xs[i], key=keys[i]) for i in range(3)]
    results = [f.result(timeout=300) for f in futs]
    server.close()
    _resolution_is_exactly_once(server, futs)
    assert inj.faults == 2
    assert server.stats["failed"] == 0
    assert server.stats["retries"] >= 2
    assert server.stats["recoveries"] >= 1
    assert any(e["event"] == "retry" for e in server.events)
    for (order, _, losses), x, k in zip(results, xs, keys):
        o_ref, _, l_ref = shuffle_soft_sort(x, HW, CFG, key=k)
        np.testing.assert_array_equal(order, o_ref)
        np.testing.assert_array_equal(losses, np.asarray(l_ref))


def test_retry_budget_exhaustion_is_a_typed_rejection():
    """A permanently failing dispatch burns the budget and resolves the
    future with RequestFailed chaining the device error — covering the
    worker exception path the old server kept under ``pragma: no
    cover``, now as load-bearing behavior."""
    def broken(*a, **k):
        raise WorkerFailure("device on fire")
    server = SortServer(HW, d=D, cfg=CFG, max_wait_ms=5.0,
                        retry=RetryPolicy(max_retries=1, backoff_base_s=0.0),
                        engine_fn=broken)
    fut = server.submit(_problems(1)[0], key=jax.random.PRNGKey(0))
    with pytest.raises(RequestFailed) as ei:
        fut.result(timeout=60)
    server.close()
    assert isinstance(ei.value.__cause__, WorkerFailure)
    assert isinstance(ei.value, RequestRejected)
    assert server.stats["failed"] == 1
    assert server.stats["retries"] == 1    # 1 retry, then terminal
    _resolution_is_exactly_once(server, [fut])


def test_mesh_dispatch_recovers_from_injected_failure():
    """Sharded dispatch recovery: the retry path re-enters the
    shard_mapped engine (CI runs this under 8 forced host devices)."""
    devs = min(2, jax.device_count())
    mesh = make_sort_mesh(devs)
    inj = FaultInjector(run_round_segment, fail_calls={1})
    server = SortServer(HW, d=D, cfg=CFG, max_wait_ms=20.0, mesh=mesh,
                        retry=RetryPolicy(max_retries=2, backoff_base_s=0.0),
                        engine_fn=inj)
    xs = _problems(2, seed=5)
    keys = [jax.random.PRNGKey(7), jax.random.PRNGKey(8)]
    futs = [server.submit(xs[i], key=keys[i]) for i in range(2)]
    results = [f.result(timeout=300) for f in futs]
    server.close()
    assert inj.faults == 1
    assert server.stats["recoveries"] >= 1
    for (order, _, _), x, k in zip(results, xs, keys):
        np.testing.assert_array_equal(
            order, shuffle_soft_sort(x, HW, CFG, key=k)[0])


def test_straggler_flagged_and_traffic_rerouted():
    """An injected slow dispatch trips the EWMA monitor and the
    scheduler reroutes: the batch bucket cap halves, so follow-up
    traffic runs in smaller device batches."""
    server = SortServer(HW, d=D, cfg=CFG, max_batch=4, autostart=False)
    # warm the compile cache through the real engine so jit time never
    # pollutes the timing baseline below
    f = server.submit(_problems(1)[0], key=jax.random.PRNGKey(0))
    _drain(server)
    assert f.result(timeout=0)
    # fresh monitor + injected delay on the 4th post-warmup dispatch
    server.straggler = StragglerMonitor(z=3.0, min_ratio=1.5, warmup=3)
    server._engine = FaultInjector(run_round_segment, delay_calls={3: 0.5})
    fut = server.submit(_problems(1, seed=9)[0], key=jax.random.PRNGKey(1))
    _drain(server)
    assert fut.result(timeout=0)
    assert server.stats["stragglers"] == 1
    assert server._bucket_cap == 2         # halved from max_batch=4
    # rerouted: a 4-request burst now splits into <=2-instance batches
    burst = [server.submit(x, key=jax.random.PRNGKey(40 + i))
             for i, x in enumerate(_problems(4, seed=11))]
    n_before = len(server.stats["batch_sizes"])
    _drain(server)
    server.close()
    assert all(f.result(timeout=0) for f in burst)
    assert max(server.stats["batch_sizes"][n_before:]) <= 2


# --------------------------------------------- backpressure / deadlines

def test_backpressure_rejects_instead_of_deadlocking():
    server = SortServer(HW, d=D, cfg=CFG, queue_depth=2, autostart=False)
    xs = _problems(3)
    f0 = server.submit(xs[0])
    f1 = server.submit(xs[1])
    with pytest.raises(QueueFull):
        server.submit(xs[2])
    assert server.stats["queue_rejected"] == 1
    # the queued (never-scheduled) futures still resolve on close —
    # rejection sheds load, it never strands what was admitted
    server.close()
    for f in (f0, f1):
        with pytest.raises(ServerClosed):
            f.result(timeout=0)


def test_deadline_expired_in_queue_is_shed_at_admission():
    server = SortServer(HW, d=D, cfg=CFG, autostart=False)
    fut = server.submit(_problems(1)[0], deadline_s=-0.001)  # already past
    server._tick()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0)
    assert server.stats["deadline_missed"] == 1
    server.close()
    _resolution_is_exactly_once(server, [fut])


def test_deadline_mid_anneal_is_shed_at_round_boundary():
    """A request whose deadline passes mid-anneal leaves at the next
    rung boundary — its committed rounds are abandoned, its batchmates
    unaffected."""
    server = SortServer(HW, d=D, cfg=CFG, max_batch=4, autostart=False)
    warm = server.submit(_problems(1)[0], key=jax.random.PRNGKey(0))
    _drain(server)
    assert warm.result(timeout=0)
    server._engine = FaultInjector(run_round_segment,
                                   delay_calls={i: 0.3 for i in range(8)})
    k = jax.random.PRNGKey(3)
    x_ok = _problems(1, seed=13)[0]
    fut = server.submit(_problems(1, seed=12)[0], deadline_s=0.45)
    f_ok = server.submit(x_ok, key=k)      # no deadline, same batches
    _drain(server)
    server.close()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0)
    assert server.stats["deadline_missed"] == 1
    np.testing.assert_array_equal(        # survivor untouched by the shed
        f_ok.result(timeout=0)[0],
        shuffle_soft_sort(x_ok, HW, CFG, key=k)[0])


def test_close_under_in_flight_load_strands_nothing():
    inj = FaultInjector(run_round_segment,
                        delay_calls={i: 0.05 for i in range(64)})
    server = SortServer(HW, d=D, cfg=CFG, max_wait_ms=5.0, engine_fn=inj)
    futs = [server.submit(x, key=jax.random.PRNGKey(i))
            for i, x in enumerate(_problems(6, seed=2))]
    time.sleep(0.15)                       # let some dispatches start
    server.close()                         # mid-flight
    for f in futs:
        assert f.done()                    # never a hang
        if f.exception() is not None:
            assert isinstance(f.exception(), ServerClosed)
    with pytest.raises(ServerClosed):
        server.submit(_problems(1)[0])


# ------------------------------------------------------ reproducibility

def test_same_seed_servers_are_bit_identical():
    """Regression for the old global np.random key default: keyless
    submits draw from a server-owned seeded stream, so same seed + same
    submission order reproduces bit-identically across servers."""
    xs = _problems(3, seed=4)

    def run(seed):
        server = SortServer(HW, d=D, cfg=CFG, max_batch=4,
                            seed=seed, autostart=False)
        futs = [server.submit(x) for x in xs]           # NO keys
        _drain(server)
        server.close()
        return [f.result(timeout=0) for f in futs]

    a, b, c = run(seed=7), run(seed=7), run(seed=8)
    for (oa, sa, la), (ob, sb, lb) in zip(a, b):
        np.testing.assert_array_equal(oa, ob)
        np.testing.assert_array_equal(sa, sb)
        np.testing.assert_array_equal(la, lb)
    assert any(not np.array_equal(x[0], y[0]) for x, y in zip(a, c))


# ------------------------------------------------------- CLI validation

def test_cli_rejects_bad_grid_with_argparse_error(capsys):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as ei:
        main(["--workload", "sort", "--sort-n", "16", "--sort-hw", "3"])
    assert ei.value.code == 2
    assert "divisor" in capsys.readouterr().err


def test_cli_rejects_bf16_without_kernel(capsys):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as ei:
        main(["--workload", "sort", "--dtype", "bfloat16"])
    assert ei.value.code == 2
    assert "--use-kernel" in capsys.readouterr().err

# --------------------------------------- adaptive annealing x faults

# Always-plateau adaptive config: every boundary past the first fires a
# schedule jump, so each request deterministically exits the anneal at
# 6 of 8 rounds — early exits happen regardless of the loss landscape.
ACFG = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=16,
                             schedule="adaptive", patience=1,
                             plateau_rtol=1.0, adapt_every=2)


@pytest.mark.parametrize("fail_calls", [
    frozenset(),           # no faults: the early-exit baseline
    frozenset({0}),        # first dispatch dies before any commit
    frozenset({1}),        # mid-anneal fault between committed rungs
    frozenset({0, 2}),     # retry storm across multiple rungs
], ids=["clean", "first", "mid", "storm"])
def test_adaptive_early_exit_resolves_exactly_once_under_faults(fail_calls):
    """Fault x adaptive-early-exit grid: when requests converge early
    during a retry storm, every future still resolves exactly once and
    bit-identical to the fault-free adaptive engine — controller state
    commits only on successful dispatches, so a replayed rung re-derives
    the same decisions."""
    xs = _problems(3, seed=9)
    keys = [jax.random.PRNGKey(20 + i) for i in range(3)]
    inj = FaultInjector(run_round_segment, fail_calls=fail_calls)
    server = SortServer(HW, d=D, cfg=ACFG, max_batch=4, autostart=False,
                        engine_fn=inj,
                        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0))
    futs = [server.submit(xs[i], key=keys[i]) for i in range(3)]
    _drain(server, max_ticks=200)
    results = [f.result(timeout=5) for f in futs]
    server.close()

    _resolution_is_exactly_once(server, futs)
    assert inj.faults == len(fail_calls)
    assert server.stats["failed"] == 0
    # A failed dispatch re-queues every request it carried (all 3 batch
    # together here), so the retry ledger counts per request.
    assert server.stats["retries"] == 3 * len(fail_calls)
    # Every request converged early: 6 of 8 rounds with this controller.
    assert server.stats["adaptive_exits"] == 3
    assert server.stats["rounds_saved"] == 3 * 2
    assert any(e["event"] == "adaptive_exit" for e in server.events)
    for (order, _, losses), x, k in zip(results, xs, keys):
        o_ref, _, l_ref = shuffle_soft_sort(x, HW, ACFG, key=k)
        np.testing.assert_array_equal(order, o_ref)
        valid = losses[~np.isnan(losses)]
        np.testing.assert_array_equal(valid, np.float32(l_ref))
        assert np.isnan(losses[len(l_ref):]).all()   # NaN past the stop


def test_adaptive_retry_exhaustion_still_resolves_every_future():
    """Even when the retry budget dies mid-adaptive-anneal the future
    resolves exactly once — with the typed rejection, not a hang."""
    inj = FaultInjector(run_round_segment, fail_calls={1, 2, 3})
    server = SortServer(HW, d=D, cfg=ACFG, autostart=False, engine_fn=inj,
                        retry=RetryPolicy(max_retries=2, backoff_base_s=0.0))
    fut = server.submit(_problems(1, seed=21)[0], key=jax.random.PRNGKey(0))
    _drain(server, max_ticks=200)
    server.close()
    with pytest.raises(RequestRejected):
        fut.result(timeout=0)
    assert server.stats["failed"] == 1
    assert server.stats["adaptive_exits"] == 0
    _resolution_is_exactly_once(server, [fut])


def test_adaptive_tournament_serving_with_fault_matches_engine():
    """n_restarts > 1: server-side adaptive tournament (cull at rung
    boundaries + per-restart early stops) recovers from an injected
    fault and still matches the engine's adaptive tournament winner."""
    from repro.core.shufflesoftsort import restart_tournament

    x = _problems(1, seed=23)[0]
    base = jax.random.PRNGKey(9)
    inj = FaultInjector(run_round_segment, fail_calls={2})
    server = SortServer(HW, d=D, cfg=ACFG, n_restarts=4,
                        tournament_rungs=2, autostart=False, engine_fn=inj,
                        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0))
    fut = server.submit(x, key=base)
    _drain(server, max_ticks=200)
    order, _, _ = fut.result(timeout=5)
    server.close()

    # The server's restart keys: base + split(fold_in(base, 1), 3).
    keys = np.concatenate(
        [np.asarray(base)[None],
         np.asarray(jax.random.split(jax.random.fold_in(base, 1), 3))])
    ref = restart_tournament(x[None], HW, ACFG, n_restarts=4,
                             keys=keys[None], cull_fraction=0.5, n_rungs=2)
    np.testing.assert_array_equal(order, ref.order[0])
    assert inj.faults == 1 and server.stats["recoveries"] >= 1
    assert server.stats["culled"] > 0
    _resolution_is_exactly_once(server, [fut])


# --------------------------------------------- warm restart (preemption)

def test_warm_restart_resolves_in_flight_futures_bit_identically():
    """close(drain=False) mid-anneal hands every unresolved request to a
    successor server, which finishes it from its last committed round
    boundary: the ORIGINAL futures resolve exactly once, bit-identical
    to uninterrupted sequential runs."""
    from repro.launch.serve import WarmHandoff

    xs = _problems(3, seed=31)
    keys = [jax.random.PRNGKey(40 + i) for i in range(3)]
    server = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False)
    futs = [server.submit(x, key=k) for x, k in zip(xs, keys)]
    server._tick()
    server._tick()                         # all requests mid-anneal
    handoff = server.close(drain=False)
    assert isinstance(handoff, WarmHandoff)
    assert len(handoff.requests) == 3      # nothing resolved yet
    assert not any(f.done() for f in futs)
    assert all(r.progress > 0 for r in handoff.requests)

    server2 = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False,
                         resume=handoff)
    assert server2.stats["resumed"] == 3
    _drain(server2)
    server2.close()
    for f, x, k in zip(futs, xs, keys):
        order, srt, losses = f.result(timeout=0)
        o_ref, s_ref, l_ref = shuffle_soft_sort(x, HW, CFG, key=k)
        np.testing.assert_array_equal(order, o_ref)
        np.testing.assert_array_equal(losses, np.asarray(l_ref))
    # exactly once, ledger split across the two generations
    assert all(f.done() for f in futs)
    terminal = sum(s.stats["completed"] + s.stats["failed"]
                   + s.stats["deadline_missed"] for s in (server, server2))
    assert terminal == len(futs)


def test_warm_restart_under_live_load_strands_nothing():
    """Threaded kill: preempt a RUNNING server mid-load; the successor
    resolves every future (result, never ServerClosed)."""
    inj = FaultInjector(run_round_segment,
                        delay_calls={i: 0.03 for i in range(64)})
    server = SortServer(HW, d=D, cfg=CFG, max_wait_ms=2.0, engine_fn=inj)
    futs = [server.submit(x, key=jax.random.PRNGKey(60 + i))
            for i, x in enumerate(_problems(6, seed=33))]
    time.sleep(0.12)                       # let some dispatches run
    handoff = server.close(drain=False)
    assert not any(f.done() and f.exception() is not None for f in futs)
    server2 = SortServer(HW, d=D, cfg=CFG, max_wait_ms=2.0,
                         resume=handoff)
    for f in futs:
        order, _, losses = f.result(timeout=120)
        assert order.shape == (N,) and np.isfinite(losses).all()
    server2.close()
    done1 = server.stats["completed"]
    assert done1 + server2.stats["completed"] == len(futs)
    assert server2.stats["resumed"] == len(handoff.requests)
    with pytest.raises(ServerClosed):
        server2.submit(_problems(1)[0])


def test_warm_restart_disk_roundtrip_adaptive(tmp_path):
    """Cross-process resume: the handoff persists to checkpoint_dir and
    a successor built with resume=<dir> (fresh futures on .resumed)
    finishes the adaptive requests bit-identical to an uninterrupted
    server — controller state round-trips through disk exactly."""
    xs = _problems(3, seed=37)
    keys = [jax.random.PRNGKey(70 + i) for i in range(3)]

    def reference():
        srv = SortServer(HW, d=D, cfg=ACFG, max_batch=8, autostart=False)
        futs = [srv.submit(x, key=k) for x, k in zip(xs, keys)]
        _drain(srv)
        srv.close()
        return [f.result(timeout=0) for f in futs]

    ref = reference()
    server = SortServer(HW, d=D, cfg=ACFG, max_batch=8, autostart=False,
                        checkpoint_dir=str(tmp_path))
    futs = [server.submit(x, key=k) for x, k in zip(xs, keys)]
    server._tick()                         # one rung committed
    server.close(drain=False)              # persists to tmp_path

    server2 = SortServer(HW, d=D, cfg=ACFG, max_batch=8, autostart=False,
                         resume=str(tmp_path))
    assert not any(f.done() for f in futs)     # gen-1 futures are dead
    assert len(server2.resumed) == 3
    _drain(server2)
    server2.close()
    got = {r.seq: r.future.result(timeout=0) for r in server2.resumed}
    for i, (o_ref, s_ref, l_ref) in enumerate(ref):
        order, srt, losses = got[i]
        np.testing.assert_array_equal(order, o_ref)
        np.testing.assert_array_equal(losses, l_ref)


def test_dispatch_divergence_sentinel_is_typed_and_exactly_once():
    """A dispatch returning non-finite losses must never commit: the
    server retries from the last finite boundary and, with a
    deterministically-poisoned engine, exhausts the budget into a
    RequestFailed caused by NumericalDivergence."""
    from repro.core.shufflesoftsort import NumericalDivergence

    def poisoned(xs, orders, keys, norms, progress, seg_len, **kw):
        o, k, l = run_round_segment(xs, orders, keys, norms, progress,
                                    seg_len, **kw)
        return o, k, np.full_like(np.asarray(l), np.nan)

    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        engine_fn=poisoned,
                        retry=RetryPolicy(max_retries=1,
                                          backoff_base_s=0.0))
    fut = server.submit(_problems(1, seed=41)[0],
                        key=jax.random.PRNGKey(5))
    for _ in range(8):
        server._tick()
        time.sleep(0.001)
    server.close()
    assert fut.done()
    exc = fut.exception()
    assert isinstance(exc, RequestFailed)
    assert isinstance(exc.__cause__, NumericalDivergence)
    _resolution_is_exactly_once(server, [fut])


# --------------------------------------- elastic capacity x brownout

def test_brownout_ladder_steps_with_hysteresis():
    """The ladder moves ONE level per tick toward the pressure target
    (eviction + straggler cap halving here) and walks back down the
    same way as capacity returns — a transient spike cannot slam a
    request to bf16 and back within one rung."""
    from repro.launch.serve import BrownoutPolicy

    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        brownout=BrownoutPolicy())
    assert server._brownout_level == 0
    server._evicted = [99]                       # capacity: device out
    server._bucket_cap = server.max_batch // 2   # capacity: cap halved
    server._update_brownout(0)
    assert server._brownout_level == 1           # one step per tick
    server._update_brownout(0)
    assert server._brownout_level == 2
    server._update_brownout(0)
    assert server._brownout_level == 2           # at target: holds
    server._evicted = []
    server._bucket_cap = server.max_batch
    server._update_brownout(0)
    assert server._brownout_level == 1           # reverts stepwise
    server._update_brownout(0)
    assert server._brownout_level == 0
    ups = [e for e in server.events if e["event"] == "brownout_up"]
    downs = [e for e in server.events if e["event"] == "brownout_down"]
    assert len(ups) == 2 and len(downs) == 2
    server.close()


def test_brownout_queue_watermarks():
    """Queue depth alone drives the ladder through the watermark
    pressure: >= high -> 2 levels, >= low -> 1, below low -> 0."""
    from repro.launch.serve import BrownoutPolicy

    server = SortServer(HW, d=D, cfg=CFG, autostart=False, queue_depth=4,
                        brownout=BrownoutPolicy())
    server._update_brownout(2)    # qfrac 0.5 >= high watermark
    server._update_brownout(2)
    assert server._brownout_level == 2
    server._update_brownout(1)    # qfrac 0.25 >= low watermark
    assert server._brownout_level == 1
    server._update_brownout(0)
    assert server._brownout_level == 0
    server.close()


def test_brownout_degrades_to_adaptive_and_matches_engine():
    """At ladder level 2 a deadline-bound request on a fixed-schedule
    server is admitted with schedule forced to "adaptive"; the result
    is bit-identical to the engine under the degraded config — the
    admitted config is immutable, so brownout trades rounds for
    latency but never correctness."""
    import dataclasses as _dc

    from repro.launch.serve import BrownoutPolicy

    x = _problems(1, seed=31)[0]
    k = jax.random.PRNGKey(7)
    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        brownout=BrownoutPolicy(slack_full_s=60.0))
    server._evicted = [99]
    server._bucket_cap = server.max_batch // 2
    server._tick()
    server._tick()                       # ladder climbs to 2
    assert server._brownout_level == 2
    fut = server.submit(x, key=k, deadline_s=30.0)   # slack < full
    _drain(server)
    order, _, _ = fut.result(timeout=5)
    server.close()
    assert server.stats["degradations"]["adaptive"] == 1
    assert server.stats["brownouts"] == 1
    ev = [e for e in server.events if e["event"] == "brownout_degrade"]
    assert ev and ev[0]["applied"] == ["adaptive"]
    o_ref, _, _ = shuffle_soft_sort(
        x, HW, _dc.replace(CFG, schedule="adaptive"), key=k)
    np.testing.assert_array_equal(order, o_ref)


def test_brownout_spares_slack_rich_requests():
    """Level 1 with no deadline takes one level less (-> 0): the
    ladder protects deadline-bound traffic; slack-rich requests keep
    full quality until pressure climbs further."""
    from repro.launch.serve import BrownoutPolicy

    x = _problems(1, seed=33)[0]
    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        brownout=BrownoutPolicy())
    server._evicted = [99]
    server._tick()
    assert server._brownout_level == 1
    fut = server.submit(x, key=jax.random.PRNGKey(3))   # no deadline
    _drain(server)
    fut.result(timeout=5)
    server.close()
    assert server.stats["brownouts"] == 0
    assert server.stats["degradations"] == {
        "culled": 0, "adaptive": 0, "banded": 0, "bf16": 0}


def test_brownout_cull_matches_aggressive_tournament():
    """The first ladder rung on a tournament server culls restarts to
    the single best at every rung edge; the result is bit-identical to
    the engine's tournament with a keep-1 cull fraction."""
    from repro.core.shufflesoftsort import restart_tournament
    from repro.launch.serve import BrownoutPolicy

    x = _problems(1, seed=37)[0]
    base = jax.random.PRNGKey(11)
    server = SortServer(HW, d=D, cfg=CFG, n_restarts=4,
                        tournament_rungs=2, autostart=False,
                        brownout=BrownoutPolicy(slack_full_s=60.0))
    server._evicted = [99]
    server._tick()
    assert server._brownout_level == 1
    fut = server.submit(x, key=base, deadline_s=30.0)
    _drain(server, max_ticks=200)
    order, _, _ = fut.result(timeout=5)
    server.close()
    assert server.stats["degradations"]["culled"] == 1
    keys = np.concatenate(
        [np.asarray(base)[None],
         np.asarray(jax.random.split(jax.random.fold_in(base, 1), 3))])
    ref = restart_tournament(x[None], HW, CFG, n_restarts=4,
                             keys=keys[None], cull_fraction=0.99,
                             n_rungs=2)
    np.testing.assert_array_equal(order, ref.order[0])


def test_warm_handoff_roundtrips_elastic_state():
    """Preemption carries the elastic state: the successor resumes at
    the same ladder position with the same evicted-device set and
    health-monitor strikes (ISSUE satellite: WarmHandoff round-trip)."""
    from repro.launch.serve import BrownoutPolicy
    from repro.runtime.fault_tolerance import DeviceLost
    from repro.runtime.straggler import DeviceHealthMonitor

    mon = DeviceHealthMonitor(lost_after=2)
    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        brownout=BrownoutPolicy(), device_health=mon)
    exc = DeviceLost("injected", device_id=3)
    assert mon.classify(exc) is None        # first strike: transient
    assert mon.classify(exc) == 3           # second strike: lost
    server._evicted = [3]
    server._brownout_level = 2
    handoff = server.close(drain=False)
    assert handoff.brownout_level == 2
    assert handoff.evicted_devices == (3,)
    assert handoff.health_state is not None

    mon2 = DeviceHealthMonitor(lost_after=2)
    server2 = SortServer(HW, d=D, cfg=CFG, autostart=False,
                         brownout=BrownoutPolicy(), device_health=mon2,
                         resume=handoff)
    assert server2._brownout_level == 2
    assert server2._evicted == [3]
    assert mon2.evicted == [3]
    server2.close()


def test_warm_handoff_disk_roundtrips_elastic_state(tmp_path):
    """Same round-trip through the on-disk handoff (cross-process
    resume): ladder position, evicted set, and monitor state all
    survive the JSON manifest."""
    from repro.launch.serve import BrownoutPolicy
    from repro.runtime.fault_tolerance import DeviceLost
    from repro.runtime.straggler import DeviceHealthMonitor

    mon = DeviceHealthMonitor(lost_after=1)
    server = SortServer(HW, d=D, cfg=CFG, autostart=False,
                        checkpoint_dir=str(tmp_path),
                        brownout=BrownoutPolicy(), device_health=mon)
    assert mon.classify(DeviceLost("injected", device_id=5)) == 5
    server._evicted = [5]
    server._brownout_level = 3
    server.close(drain=False)              # persists to tmp_path

    mon2 = DeviceHealthMonitor()
    server2 = SortServer(HW, d=D, cfg=CFG, autostart=False,
                         brownout=BrownoutPolicy(), device_health=mon2,
                         resume=str(tmp_path))
    assert server2._brownout_level == 3
    assert server2._evicted == [5]
    assert mon2.evicted == [5]
    assert mon2.strikes == {5: 1}
    server2.close()
