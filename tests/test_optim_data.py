"""Optimizer + data-pipeline substrate tests (unit + property)."""
import dataclasses

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # degrade gracefully where absent
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.optim.adam import (
    adam_init,
    adam_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
)
from repro.data.synthetic import synthetic_batch
from repro.configs import get_config
from repro.models import reduced_config


# -------------------------------------------------------------------- adam

def test_adam_converges_quadratic():
    target = jnp.array([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3), "nested": ({"v": jnp.ones(2)},)}
    state = adam_init(params)
    for _ in range(400):
        grads = {"w": 2 * (params["w"] - target),
                 "nested": ({"v": 2 * params["nested"][0]["v"]},)}
        params, state = adam_update(grads, state, params, lr=0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(params["nested"][0]["v"]),
                               np.zeros(2), atol=1e-2)


def test_adam_handles_tuple_containers():
    """Regression: block stacks are tuples; the update must preserve
    arbitrary container types (the _Upd holder bug)."""
    params = ({"a": jnp.ones(4)}, {"b": jnp.ones(3)})
    state = adam_init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    new_params, new_state = adam_update(grads, state, params, lr=0.1)
    assert isinstance(new_params, tuple) and len(new_params) == 2
    assert float(new_params[0]["a"][0]) < 1.0
    assert int(new_state.step) == 1


def test_adam_bf16_moments():
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adam_init(params, moment_dtype=jnp.bfloat16)
    assert state.mu["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full(8, 0.5, jnp.bfloat16)}
    new_params, _ = adam_update(grads, state, params, lr=0.01)
    assert new_params["w"].dtype == jnp.bfloat16
    assert float(new_params["w"][0]) < 1.0


@given(st.floats(0.1, 10.0), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm_property(scale, seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (16,)) * scale}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4
    if float(norm) <= 1.0:   # no-op below threshold
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-5)


def test_schedules_monotone_shapes():
    cos = cosine_schedule(1e-3, 100)
    assert float(cos(jnp.int32(0))) == pytest.approx(1e-3)
    assert float(cos(jnp.int32(100))) == pytest.approx(1e-4, rel=0.1)
    wc = linear_warmup_cosine(1e-3, 10, 100)
    assert float(wc(jnp.int32(5))) < float(wc(jnp.int32(10)))


# -------------------------------------------------------------------- data

def test_synthetic_batch_deterministic_across_calls():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    b1 = synthetic_batch(cfg, 4, 16, step=7)
    b2 = synthetic_batch(cfg, 4, 16, step=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_synthetic_batch_differs_across_steps():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    b1 = synthetic_batch(cfg, 4, 16, step=1)
    b2 = synthetic_batch(cfg, 4, 16, step=2)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b2["tokens"]))


def test_synthetic_labels_are_shifted_tokens():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    b = synthetic_batch(cfg, 2, 32, step=0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_synthetic_context_for_modalities():
    vlm = reduced_config(get_config("llama-3.2-vision-90b"))
    b = synthetic_batch(vlm, 2, 8, step=0)
    assert b["context"].shape == (2, vlm.vision_tokens, vlm.vision_d)
    aud = reduced_config(get_config("whisper-small"))
    b = synthetic_batch(aud, 2, 8, step=0)
    assert b["context"].shape == (2, aud.audio_frames, aud.d_model)


# -------------------------------------------- MoE implementation equivalence

def test_moe_gather_equals_einsum_fwd_and_grads():
    """The §Perf gather dispatch must stay bit-compatible with the
    baseline einsum dispatch (same drops, same gates, same grads)."""
    from repro.models.moe import init_moe, moe_ffn, moe_ffn_gather
    from repro.models.layers import AxisRules
    cfg = reduced_config(get_config("granite-moe-3b-a800m"))
    cfg = dataclasses.replace(cfg, moe_group_size=16)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32,
                         AxisRules())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

    y1, a1 = moe_ffn(params, cfg, x)
    y2, a2 = moe_ffn_gather(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1["moe_balance"]),
                               float(a2["moe_balance"]), rtol=1e-6)

    def loss(fn):
        def f(p, x):
            y, a = fn(p, cfg, x)
            return jnp.sum(y ** 2) + a["moe_balance"] + a["router_z"]
        return f

    g1 = jax.grad(loss(moe_ffn))(params, x)
    g2 = jax.grad(loss(moe_ffn_gather))(params, x)
    for k in g1:
        scale = float(jnp.max(jnp.abs(g1[k]))) + 1e-9
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   atol=1e-4 * scale)
