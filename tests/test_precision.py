"""Mixed-precision (bf16) kernel parity suite — hypothesis-free.

The kernel tier's ``compute_dtype="bfloat16"`` contract
(``repro/kernels/softsort_apply.py`` docstring): keys, softmax stats,
accumulators and key/tau gradients stay f32; scores are rounded to
bf16; payload-sided arrays ride bf16 in HBM and through the MXU.  The
principled tolerance that follows: bf16 rounding is 2^-8 ~ 0.4%
relative per quantization, the forward applies it to the scores (error
amplified by exp only where p is already large, so ~proportional) and
once to the payload product, and the backward stacks a handful of such
factors — the documented envelope is 2e-2 relative (observed <= ~6e-3
across this suite and the bench sweep), against f32 references.

Also asserts the f32 path is UNCHANGED by the mixed-precision plumbing
(compute_dtype="float32" must match the default exactly), the
tie-heavy-keys behaviour (bf16 score rounding manufactures ties; the
committed permutation comes from argsort of the f32 keys and must stay
valid, and the kernel outputs must stay finite), and hosts the
row-chunked ``mean_pairwise_distance`` regression (satellite of the
same PR: the exact path no longer materializes the (N, N, d)
broadcast).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.losses import mean_pairwise_distance
from repro.core.softsort import (
    hard_permutation,
    is_valid_permutation,
    softsort_apply_banded as banded_oracle,
)
from repro.kernels.ops import softsort_apply, softsort_apply_banded
from repro.kernels.ref import softsort_apply_ref

BF16_TOL = 2e-2          # the documented bf16 envelope (EXPERIMENTS §Perf)


def _loss_of(apply_fn, a, b):
    def f(w, x, tau):
        y, c = apply_fn(w, x, tau)
        return jnp.sum(y * a) + jnp.sum(c * b)
    return f


def _relerr(got, want):
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    return float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32)
                                 - want))) / scale


def _problem(n, d, key=0, scale=3.0):
    keys = jax.random.split(jax.random.PRNGKey(key), 4)
    w = jax.random.normal(keys[0], (n,)) * scale
    x = jax.random.normal(keys[1], (n, d))
    a = jax.random.normal(keys[2], (n, d))
    b = jax.random.normal(keys[3], (n,))
    return w, x, a, b


# ------------------------------------------------ bf16 vs f32 parity

@pytest.mark.parametrize("n,d", [(100, 7), (300, 3), (129, 17)])
def test_bf16_fused_forward_parity(n, d):
    w, x, _, _ = _problem(n, d, key=n + d)
    y, c = softsort_apply(w, x, 0.6, compute_dtype="bfloat16")
    yr, cr = softsort_apply_ref(w, x, 0.6)
    assert y.dtype == jnp.float32          # public output is upcast
    assert _relerr(y, yr) < BF16_TOL
    assert _relerr(c, cr) < BF16_TOL


@pytest.mark.parametrize("n,d", [(100, 7), (300, 3)])
def test_bf16_fused_gradient_parity(n, d):
    """dw, dx AND dtau against the f32 dense oracle."""
    w, x, a, b = _problem(n, d, key=3 * n + d)
    gk = jax.grad(_loss_of(
        lambda w, x, t: softsort_apply(w, x, t, compute_dtype="bfloat16"),
        a, b), argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    gr = jax.grad(_loss_of(softsort_apply_ref, a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    for got, want in zip(gk, gr):
        assert _relerr(got, np.asarray(want, np.float32)) < BF16_TOL


@pytest.mark.parametrize("n,d,band", [(200, 5, 32), (300, 8, 64)])
def test_bf16_banded_parity(n, d, band):
    """Banded bf16 vs the windowed f32 jnp oracle — the same truncation,
    so the comparison isolates pure precision error: fwd, colsum, and
    all three gradients."""
    w, x, a, b = _problem(n, d, key=7 * n + d)
    tau = jnp.float32(0.3)
    y, c = softsort_apply_banded(w, x, tau, band, compute_dtype="bfloat16")
    yo, co = banded_oracle(w, x, tau, band)
    assert _relerr(y, yo) < BF16_TOL
    assert _relerr(c, co) < BF16_TOL
    gk = jax.grad(_loss_of(
        lambda w, x, t: softsort_apply_banded(
            w, x, t, band, compute_dtype="bfloat16"), a, b),
        argnums=(0, 1, 2))(w, x, tau)
    go = jax.grad(_loss_of(
        lambda w, x, t: banded_oracle(w, x, t, band), a, b),
        argnums=(0, 1, 2))(w, x, tau)
    for got, want in zip(gk, go):
        assert _relerr(got, np.asarray(want, np.float32)) < BF16_TOL


def test_bf16_batched_matches_per_instance():
    """The bf16 tier under a leading batch axis is B independent
    problems, exactly like the f32 tier."""
    bsz, n, d = 3, 100, 5
    keys = jax.random.split(jax.random.PRNGKey(11), 2)
    w = jax.random.normal(keys[0], (bsz, n)) * 2
    x = jax.random.normal(keys[1], (bsz, n, d))
    y, c = softsort_apply(w, x, 0.5, compute_dtype="bfloat16")
    for bi in range(bsz):
        yi, ci = softsort_apply(w[bi], x[bi], 0.5,
                                compute_dtype="bfloat16")
        np.testing.assert_array_equal(np.asarray(y[bi]), np.asarray(yi))
        np.testing.assert_array_equal(np.asarray(c[bi]), np.asarray(ci))


# ----------------------------------- f32 path unchanged by the plumbing

@pytest.mark.parametrize("banded", [False, True])
def test_f32_compute_dtype_is_identity(banded):
    """compute_dtype='float32' must be bit-identical to the default
    call — the mixed-precision casts are exact no-ops at f32."""
    w, x, a, b = _problem(150, 6, key=42)
    if banded:
        fn = lambda w, x, t, **kw: softsort_apply_banded(w, x, t, 32, **kw)
    else:
        fn = softsort_apply
    y0, c0 = fn(w, x, 0.5)
    y1, c1 = fn(w, x, 0.5, compute_dtype="float32")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    g0 = jax.grad(_loss_of(lambda w, x, t: fn(w, x, t), a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.5))
    g1 = jax.grad(_loss_of(
        lambda w, x, t: fn(w, x, t, compute_dtype="float32"), a, b),
        argnums=(0, 1, 2))(w, x, jnp.float32(0.5))
    for p0, p1 in zip(g0, g1):
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


# -------------------------------------------------- tie-heavy keys

def test_bf16_tie_heavy_keys_valid_hard_permutation():
    """bf16 score rounding manufactures exact ties (beyond the ones the
    key vector already has); the committed permutation is argsort of
    the F32 keys, so it must remain a valid permutation, and the bf16
    kernel outputs must stay finite with row-stochastic mass."""
    n, d = 256, 4
    # Keys with heavy duplication: only 16 distinct values across 256
    # slots, plus a tiny spread that bf16 rounding collapses back into
    # ties at score scale.
    base = jnp.repeat(jnp.arange(16, dtype=jnp.float32), n // 16)
    jitter = jax.random.uniform(jax.random.PRNGKey(0), (n,)) * 1e-3
    w = jax.random.permutation(jax.random.PRNGKey(1), base + jitter)
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))

    perm = np.asarray(hard_permutation(w))
    assert is_valid_permutation(perm)

    for fn in (
        lambda: softsort_apply(w, x, 0.05, compute_dtype="bfloat16"),
        lambda: softsort_apply_banded(w, x, 0.05, 32,
                                      compute_dtype="bfloat16"),
    ):
        y, c = fn()
        assert bool(jnp.all(jnp.isfinite(y)))
        assert bool(jnp.all(jnp.isfinite(c)))
        # Total colsum mass is N (each row of P sums to 1) whatever the
        # tie structure — ties redistribute mass between columns only.
        np.testing.assert_allclose(float(c.sum()), n, rtol=1e-2)

    # End-to-end: a short bf16-kernel training run on tie-heavy data
    # still commits valid permutations.
    from repro.core.shufflesoftsort import (
        ShuffleSoftSortConfig,
        shuffle_soft_sort,
    )
    xs_grid = jnp.repeat(jax.random.normal(jax.random.PRNGKey(3),
                                           (16, 3)), 4, axis=0)   # dup rows
    cfg = ShuffleSoftSortConfig(rounds=2, inner_steps=2, use_kernel=True,
                                compute_dtype="bfloat16",
                                chunk=64)
    order, _, losses = shuffle_soft_sort(xs_grid, (8, 8), cfg,
                                         key=jax.random.PRNGKey(4))
    assert is_valid_permutation(order)
    assert np.isfinite(losses).all()


# ------------------------------------- engine bit-identity under bf16

@pytest.mark.parametrize("compute_dtype", ["float32", "bfloat16"])
def test_engines_bit_identical_per_seed_fixed_dtype(compute_dtype):
    """Sequential vs batched engines stay bit-identical per seed within
    one fixed (dtype, block) choice — precision and tiling are static
    trace-time choices, identical across engines."""
    from repro.core.shufflesoftsort import (
        ShuffleSoftSortConfig,
        shuffle_soft_sort,
        shuffle_soft_sort_batched,
    )
    n, d = 64, 3
    xs = jax.random.normal(jax.random.PRNGKey(9), (2, n, d))
    cfg = ShuffleSoftSortConfig(rounds=3, inner_steps=2, use_kernel=True,
                                compute_dtype=compute_dtype, chunk=64)
    keys = jax.random.split(jax.random.PRNGKey(17), 2).reshape(2, 1, 2)
    res = shuffle_soft_sort_batched(xs, (8, 8), cfg, n_restarts=1,
                                    keys=keys)
    for bi in range(2):
        order, _, losses = shuffle_soft_sort(
            xs[bi], (8, 8), cfg, key=jnp.asarray(keys[bi, 0]))
        np.testing.assert_array_equal(res.order[bi], order)
        np.testing.assert_allclose(res.losses[bi], np.asarray(losses),
                                   rtol=0, atol=0)


# ------------------------- satellite: chunked mean_pairwise_distance

def test_mean_pairwise_distance_chunked_regression():
    """The exact path now streams row chunks instead of materializing
    the (N, N, d) broadcast.  The summed distances are mathematically
    identical; chunking only reassociates the f32 reduction, so the
    result agrees with the old all-at-once formula to a few ULP (XLA's
    own (N, N)->scalar reduction order is already tiling-dependent, so
    exact bit-matching is not achievable by ANY reassociated rewrite —
    what matters downstream, eager vmap == plain, is asserted below
    bitwise)."""
    def old_exact(x):
        n = x.shape[0]
        d = jnp.sqrt(jnp.sum(jnp.square(x[:, None] - x[None, :]),
                             axis=-1) + 1e-12)
        return d.sum() / (n * (n - 1))

    x_small = jax.random.normal(jax.random.PRNGKey(0), (200, 5))

    # Reassociation only — a few ULP against the old formula.
    for n, d in [(200, 5), (300, 5), (1000, 3), (2048, 8)]:
        x = jax.random.normal(jax.random.PRNGKey(n), (n, d))
        got = float(mean_pairwise_distance(x))
        want = float(old_exact(x))
        np.testing.assert_allclose(got, want, rtol=5e-7)

    # The eager vmap the batched engines use must bit-match the plain
    # call (this is what carries the sequential-vs-batched bit-identity
    # contract through the norm).
    xs = jax.random.normal(jax.random.PRNGKey(7), (3, 300, 4))
    plain = np.asarray([float(mean_pairwise_distance(xs[i]))
                        for i in range(3)], np.float32)
    vmapped = np.asarray(jax.vmap(mean_pairwise_distance)(xs), np.float32)
    np.testing.assert_array_equal(plain, vmapped)

    # Gradients flow through the chunked stream.
    g = jax.grad(lambda x: mean_pairwise_distance(x))(x_small)
    assert bool(jnp.all(jnp.isfinite(g)))
