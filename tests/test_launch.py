"""Launcher-layer tests that don't need 512 devices: HLO collective
parser, spec sanitizer, roofline math, and the CPU-scale train/serve
drivers (end-to-end system behaviour)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------- collective parse

HLO_SAMPLE = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = bf16[16,1024]{1,0} all-gather-done(%ag)
"""


def test_collective_stats_parser():
    from repro.launch.dryrun import collective_stats
    st = collective_stats(HLO_SAMPLE)
    assert st["all-gather"]["count"] == 1
    # all-gather: result 16*1024*2 B * (g-1)/g with g=4
    np.testing.assert_allclose(st["all-gather"]["bytes"],
                               16 * 1024 * 2 * 3 / 4)
    # all-reduce: 2 * size * (g-1)/g, g=2
    np.testing.assert_allclose(st["all-reduce"]["bytes"],
                               2 * 256 * 4 * 1 / 2)
    # reduce-scatter: result * (g-1), g=4
    np.testing.assert_allclose(st["reduce-scatter"]["bytes"],
                               64 * 4 * 3)
    assert st["collective-permute"]["count"] == 1
    assert st["total_bytes"] > 0


def test_shape_bytes_tuple_types():
    from repro.launch.dryrun import _shape_bytes
    assert _shape_bytes("bf16[8,4]") == 64
    assert _shape_bytes("(f32[2,2], s8[16])") == 32


# -------------------------------------------------------- spec sanitizer

def test_sanitize_spec_drops_indivisible_axes():
    from repro.launch.steps import _sanitize_spec
    mesh = jax.make_mesh((1,), ("data",))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    s = _sanitize_spec(m, P("model", "data"), (40, 1536))
    assert s == P(None, "data")          # 40 % 16 != 0 -> dropped
    s = _sanitize_spec(m, P("model", None), (512, 7))
    assert s == P("model", None)
    s = _sanitize_spec(m, P(("data", "model"), None), (512, 7))
    assert s == P(("data", "model"), None)
    s = _sanitize_spec(m, P(("data", "model"), None), (128, 7))
    assert s == P(None, None)            # 128 % 256 != 0


# ---------------------------------------------------------- roofline math

def test_roofline_analyze_toy_record():
    from benchmarks.roofline import analyze, PEAK_FLOPS
    rec = {
        "arch": "qwen1.5-0.5b", "shape": "train_4k", "mesh": "single",
        "kind": "train", "status": "ok",
        "roofline_inputs": {"flops": 1e13, "bytes_accessed": 1e12,
                            "collective_bytes": 1e11},
    }
    rows = analyze([rec])
    assert len(rows) == 1
    r = rows[0]
    np.testing.assert_allclose(r["compute_s"], 1e13 / PEAK_FLOPS)
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_ratio"] < 2.0
    assert r["roofline_frac"] <= 1.0 + 1e-6


def test_active_param_counts_moe_scaling():
    from benchmarks.roofline import active_param_counts
    a_moe, e_moe = active_param_counts("granite-moe-3b-a800m")
    a_dense, _ = active_param_counts("qwen1.5-0.5b")
    assert a_moe > 0 and e_moe > 0
    # granite: top-8 of 40 experts -> active far below total
    from repro.configs import get_config
    from repro.models import model as model_lib
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(
        jax.eval_shape(lambda k: model_lib.init_model(
            k, get_config("granite-moe-3b-a800m"))[0],
            jax.random.PRNGKey(0))))
    assert a_moe < 0.45 * total


# ----------------------------------------------------- end-to-end drivers

def test_train_driver_loss_decreases(tmp_path):
    from repro.launch.train import main
    stats = main(["--preset", "tiny", "--steps", "40", "--batch", "4",
                  "--seq", "32", "--ckpt-dir", str(tmp_path),
                  "--lr", "1e-3"])
    assert stats["steps"] == 40
    assert stats["last_loss"] < stats["first_loss"]


def test_train_driver_failure_recovery(tmp_path):
    from repro.launch.train import main
    stats = main(["--preset", "tiny", "--steps", "30", "--batch", "2",
                  "--seq", "16", "--ckpt-dir", str(tmp_path),
                  "--fail-at", "15", "--ckpt-every", "10"])
    assert stats["restarts"] == 1
    assert stats["steps"] == 30


def test_train_driver_with_compression(tmp_path):
    from repro.launch.train import main
    stats = main(["--preset", "tiny", "--steps", "30", "--batch", "2",
                  "--seq", "16", "--ckpt-dir", str(tmp_path),
                  "--compress-grads", "--lr", "1e-3"])
    assert stats["last_loss"] < stats["first_loss"]


def test_serve_driver_batched_requests():
    from repro.launch.serve import main
    stats = main(["--preset", "tiny", "--requests", "4", "--max-new", "8"])
    assert stats["tok_per_s"] > 0
