"""Elastic-capacity proofs (EXPERIMENTS.md §Robustness, "Elastic
capacity"): losing a mesh device mid-anneal must cost exactly one rung
replay and zero correctness.

Three layers, matching the production stack:

* **Chaos primitives** — ``FaultInjector``'s ``device_loss`` /
  ``device_return`` schedules flip a persistent down-set at exact
  dispatch indices; every dispatch whose ``mesh=`` contains a downed
  device raises ``DeviceLost`` naming it.  Deterministic, so the tests
  know precisely which dispatch died.
* **Classification** — ``DeviceHealthMonitor`` turns named failures
  into evictions after a strike budget, clears strikes on success, and
  detects grown-back devices through a health probe.
* **Re-shard bit-identity** — the rung carry is layout-free host numpy
  (see ``runtime.anneal_checkpoint``), so rebuilding the mesh over the
  survivors at a rung boundary (``mesh_hook=``) and re-padding changes
  NOTHING about the math: every engine x eviction point must be
  bit-identical to an uninterrupted run on the original mesh.

The mesh grids need >= 8 devices; on single-device hosts those cases
skip and a subprocess re-runs the core identity + server-eviction
checks under ``--xla_force_host_platform_device_count=8`` so the
elastic path is always exercised.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    restart_tournament,
    run_round_segment,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.launch.mesh import make_sort_mesh
from repro.launch.serve import BrownoutPolicy, SortServer
from repro.runtime.fault_tolerance import (
    DeviceLost,
    FaultInjector,
    RetryPolicy,
    WorkerFailure,
)
from repro.runtime.straggler import DeviceHealthMonitor

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >= 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N, HW, D = 16, (4, 4), 2
CFG = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
ACFG = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=16,
                             schedule="adaptive", patience=1,
                             plateau_rtol=1.0, adapt_every=2)


def _problems(count, d=D, n=N, seed=0):
    rng = np.random.RandomState(seed)
    return rng.rand(count, n, d).astype(np.float32)


def _drain(server, max_ticks=200):
    import time
    for _ in range(max_ticks):
        with server._cv:
            idle = not server._pending and not server._active
        if idle:
            return
        server._tick()
        time.sleep(0.001)
    raise AssertionError("server did not go idle")


# ------------------------------------ FaultInjector device chaos mode

def test_fault_injector_device_loss_is_persistent_until_return():
    """The down-set is state, not a one-shot schedule: every dispatch
    whose mesh holds the dead device raises DeviceLost (what a fleet
    looks like between failure and re-shard), until device_return."""
    mesh = make_sort_mesh(1)
    dev = list(mesh.devices.flat)[0].id
    inj = FaultInjector(lambda **kw: "ok",
                        device_loss={1: dev}, device_return={4: dev})
    assert inj(mesh=mesh) == "ok"            # dispatch 0: healthy
    assert inj.healthy(dev)
    for i in (1, 2):                         # 1: goes down; 2: still down
        with pytest.raises(DeviceLost) as ei:
            inj(mesh=mesh)
        assert ei.value.device_id == dev, i
    assert not inj.healthy(dev)
    assert inj(mesh=None) == "ok"            # vmap engine: no device slots
    assert inj(mesh=mesh) == "ok"            # dispatch 4: device returns
    assert inj.healthy(dev)
    assert inj.calls == 5
    assert inj.device_faults == 2


def test_fault_injector_device_lost_is_a_worker_failure():
    """DeviceLost subclasses WorkerFailure, so retry plumbing that
    predates the health layer still treats it as a dispatch failure."""
    assert issubclass(DeviceLost, WorkerFailure)
    e = DeviceLost("gone", device_id=7)
    assert e.device_id == 7


def test_fault_injector_device_state_roundtrips():
    """A chaos scenario survives a WarmHandoff: cursor, schedules, and
    the down-set all round-trip, so the resumed injector keeps raising
    for still-down devices and fires pending returns on schedule."""
    mesh = make_sort_mesh(1)
    dev = list(mesh.devices.flat)[0].id
    inj = FaultInjector(lambda **kw: "ok",
                        device_loss={0: dev}, device_return={2: dev})
    with pytest.raises(DeviceLost):
        inj(mesh=mesh)
    state = inj.state_dict()

    inj2 = FaultInjector(lambda **kw: "ok")
    inj2.load_state_dict(state)
    assert inj2.down == {dev}
    assert inj2.calls == 1 and inj2.device_faults == 1
    with pytest.raises(DeviceLost):          # dispatch 1: still down
        inj2(mesh=mesh)
    assert inj2(mesh=mesh) == "ok"           # dispatch 2: scheduled return


# ------------------------------------------------ DeviceHealthMonitor

def test_health_monitor_strike_budget_and_eviction_order():
    mon = DeviceHealthMonitor(lost_after=2)
    e3, e5 = DeviceLost("x", device_id=3), DeviceLost("x", device_id=5)
    assert mon.classify(e3) is None          # strike 1: transient
    assert mon.classify(e5) is None
    assert mon.classify(e5) == 5             # strike 2: lost
    assert mon.classify(e3) == 3
    assert mon.evicted == [5, 3]             # eviction order preserved
    # an evicted device's late failures are absorbed (raced the re-shard)
    assert mon.classify(e5) is None


def test_health_monitor_anonymous_failures_are_transient():
    mon = DeviceHealthMonitor(lost_after=1)
    assert mon.classify(WorkerFailure("anon")) is None
    assert mon.classify(ValueError("nope")) is None
    assert mon.evicted == [] and mon.strikes == {}


def test_health_monitor_success_clears_strikes():
    """Intermittent flakes never accumulate into a false eviction."""
    mon = DeviceHealthMonitor(lost_after=2)
    for _ in range(3):
        assert mon.classify(DeviceLost("x", device_id=4)) is None
        mon.record_success([4])
    assert mon.evicted == []


def test_health_monitor_poll_returns_uses_probe():
    mon = DeviceHealthMonitor(lost_after=1)
    mon.classify(DeviceLost("x", device_id=1))
    mon.classify(DeviceLost("x", device_id=2))
    assert mon.poll_returns(probe=lambda d: d == 2) == [2]
    assert mon.evicted == [1]
    assert mon.poll_returns(probe=lambda d: False) == []
    # no probe at all -> nothing to ask, nothing returns
    assert DeviceHealthMonitor().poll_returns() == []


def test_health_monitor_state_roundtrips():
    mon = DeviceHealthMonitor(lost_after=3)
    mon.classify(DeviceLost("x", device_id=9))
    mon.classify(DeviceLost("x", device_id=2))
    mon.classify(DeviceLost("x", device_id=2))
    mon.classify(DeviceLost("x", device_id=2))
    mon2 = DeviceHealthMonitor()
    mon2.load_state_dict(mon.state_dict())
    assert mon2.lost_after == 3
    assert mon2.strikes == {9: 1, 2: 3}
    assert mon2.evicted == [2]


def test_health_monitor_validates_budget():
    with pytest.raises(ValueError, match="lost_after"):
        DeviceHealthMonitor(lost_after=0)


# ----------------------- rung-boundary re-shard: engine bit-identity

def _record_boundaries(engine, xs, keys):
    """Dry run with a no-op mesh_hook to learn where the engine fires
    rung boundaries (returning None leaves the mesh untouched)."""
    starts: list[int] = []

    def hook(start, mesh):
        starts.append(int(start))
        return None

    _run_engine(engine, xs, keys, mesh=None, hook=hook)
    return starts


def _run_engine(engine, xs, keys, mesh, hook):
    if engine == "tournament":
        r = restart_tournament(xs, HW, CFG, n_restarts=4, keys=keys,
                               cull_fraction=0.5, n_rungs=2, mesh=mesh,
                               mesh_hook=hook)
        return np.asarray(r.order), np.asarray(r.all_losses)
    cfg = ACFG if engine == "adaptive" else CFG
    r = shuffle_soft_sort_batched(xs, HW, cfg, n_restarts=2,
                                  keys=keys, mesh=mesh, mesh_hook=hook)
    return np.asarray(r.all_orders), np.asarray(r.all_losses)


def _evict_hook(dead_id, at_round):
    """Re-shard over the survivors when the anneal reaches rung
    ``at_round`` — the in-memory move the SortServer makes after a
    DeviceHealthMonitor eviction."""
    def hook(start, mesh):
        if mesh is None or start != at_round:
            return None
        survivors = [dv for dv in mesh.devices.flat if dv.id != dead_id]
        if len(survivors) == len(list(mesh.devices.flat)):
            return None
        return make_sort_mesh(len(survivors), devices=survivors)
    return hook


@multi_device
@pytest.mark.parametrize("engine", ["fixed", "adaptive", "tournament"])
def test_elastic_reshard_is_bit_identical_per_slot(engine):
    """The acceptance grid: for every mesh slot k, evict k's device at
    rung (k mod n_boundaries) and the run must be bit-identical to the
    uninterrupted 8-device run — the carry is layout-free, so the mesh
    swap is invisible to the math."""
    if engine == "tournament":
        xs = _problems(2, seed=5)
        keys = np.asarray(
            jax.random.split(jax.random.PRNGKey(2), 2 * 4),
            np.uint32).reshape(2, 4, 2)
    else:
        xs = _problems(3, seed=5)
        keys = jax.random.split(jax.random.PRNGKey(2), 3 * 2)
    boundaries = _record_boundaries(engine, xs, keys)
    assert boundaries, "engine fired no rung boundaries"
    mesh = make_sort_mesh(8)
    ref = _run_engine(engine, xs, keys, mesh=mesh, hook=None)
    for k, dv in enumerate(mesh.devices.flat):
        at = boundaries[k % len(boundaries)]
        got = _run_engine(engine, xs, keys, mesh=make_sort_mesh(8),
                          hook=_evict_hook(dv.id, at))
        np.testing.assert_array_equal(got[0], ref[0], err_msg=(
            f"slot {k} (device {dv.id}) evicted at round {at}"))
        np.testing.assert_array_equal(got[1], ref[1])


@multi_device
def test_elastic_reshard_survives_cascading_loss():
    """Evict at one boundary, evict AGAIN at a later one (8 -> 7 -> 6
    devices): still bit-identical — each re-shard is independent."""
    xs = _problems(3, seed=7)
    keys = jax.random.split(jax.random.PRNGKey(4), 3 * 2)
    boundaries = _record_boundaries("fixed", xs, keys)
    assert len(boundaries) >= 2
    mesh = make_sort_mesh(8)
    devs = list(mesh.devices.flat)
    ref = _run_engine("fixed", xs, keys, mesh=mesh, hook=None)

    h1 = _evict_hook(devs[1].id, boundaries[0])
    h2 = _evict_hook(devs[6].id, boundaries[-1])

    def cascade(start, m):
        return h2(start, m) or h1(start, m)

    got = _run_engine("fixed", xs, keys, mesh=make_sort_mesh(8),
                      hook=cascade)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])


# --------------------------------------- server-level eviction proofs

@multi_device
def test_server_eviction_reshards_once_and_stays_bit_identical():
    """One injected device loss: the health layer evicts it, re-shards
    over the 7 survivors within one rung boundary (the dead device
    faults exactly one dispatch), the rung replays WITHOUT spending
    retry budget, and every result matches the sequential engine."""
    mesh = make_sort_mesh(8)
    dead = list(mesh.devices.flat)[3].id
    inj = FaultInjector(run_round_segment, device_loss={1: dead})
    mon = DeviceHealthMonitor(lost_after=1, probe=inj.healthy)
    server = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False,
                        mesh=mesh, engine_fn=inj, device_health=mon,
                        retry=RetryPolicy(max_retries=2,
                                          backoff_base_s=0.0))
    xs = _problems(3, seed=11)
    futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
            for i in range(3)]
    _drain(server)
    results = [f.result(timeout=5) for f in futs]
    server.close()

    assert server.stats["evictions"] == 1
    assert server.stats["reshards"] == server.stats["evictions"] == 1
    assert server.stats["retries"] == 0      # eviction spends no budget
    assert server.stats["failed"] == 0
    # detection -> re-shard gap is exactly one rung boundary: the dead
    # device faulted exactly one dispatch, every later rung ran clean
    assert inj.device_faults == 1
    assert server.mesh is not None
    assert int(server.mesh.shape["data"]) == 7
    ev = [e for e in server.events if e["event"] == "evict"]
    assert len(ev) == 1 and ev[0]["device"] == dead
    assert ev[0]["survivors"] == 7 and ev[0]["requeued"] == 3
    for i, (order, _, _) in enumerate(results):
        o_ref, _, _ = shuffle_soft_sort(xs[i], HW, CFG,
                                        key=jax.random.PRNGKey(i))
        np.testing.assert_array_equal(order, o_ref)


@multi_device
def test_server_device_return_grows_mesh_back():
    """A returned device rejoins at a tick boundary: the mesh grows
    back to 8, device_returns counts it, and results stay exact."""
    mesh = make_sort_mesh(8)
    dead = list(mesh.devices.flat)[5].id
    inj = FaultInjector(run_round_segment, device_loss={0: dead},
                        device_return={2: dead})
    mon = DeviceHealthMonitor(lost_after=1, probe=inj.healthy)
    server = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False,
                        mesh=mesh, engine_fn=inj, device_health=mon,
                        retry=RetryPolicy(max_retries=2,
                                          backoff_base_s=0.0))
    xs = _problems(2, seed=13)
    futs = [server.submit(xs[i], key=jax.random.PRNGKey(30 + i))
            for i in range(2)]
    _drain(server)
    results = [f.result(timeout=5) for f in futs]
    server.close()

    assert server.stats["evictions"] == 1
    assert server.stats["reshards"] == 1
    assert server.stats["device_returns"] == 1
    assert server._evicted == []
    assert int(server.mesh.shape["data"]) == 8
    assert any(e["event"] == "device_return" for e in server.events)
    for i, (order, _, _) in enumerate(results):
        o_ref, _, _ = shuffle_soft_sort(xs[i], HW, CFG,
                                        key=jax.random.PRNGKey(30 + i))
        np.testing.assert_array_equal(order, o_ref)


@multi_device
def test_server_losing_every_device_falls_back_to_vmap():
    """Total mesh loss degrades to the vmap engine (mesh=None) rather
    than failing requests: capacity goes to the host, not to zero."""
    mesh = make_sort_mesh(2, devices=list(jax.devices())[:2])
    ids = [dv.id for dv in mesh.devices.flat]
    inj = FaultInjector(run_round_segment,
                        device_loss={0: ids[0], 2: ids[1]})
    mon = DeviceHealthMonitor(lost_after=1, probe=inj.healthy)
    server = SortServer(HW, d=D, cfg=CFG, max_batch=4, autostart=False,
                        mesh=mesh, engine_fn=inj, device_health=mon,
                        retry=RetryPolicy(max_retries=2,
                                          backoff_base_s=0.0))
    x = _problems(1, seed=17)[0]
    fut = server.submit(x, key=jax.random.PRNGKey(6))
    _drain(server)
    order, _, _ = fut.result(timeout=5)
    server.close()
    assert server.stats["evictions"] == 2
    assert server.stats["reshards"] == 2
    assert server.mesh is None
    o_ref, _, _ = shuffle_soft_sort(x, HW, CFG, key=jax.random.PRNGKey(6))
    np.testing.assert_array_equal(order, o_ref)


@multi_device
def test_eviction_raises_brownout_ladder():
    """An eviction is a capacity signal: with a BrownoutPolicy armed,
    the ladder climbs after the evict and steps back down once the
    device returns (the full control loop, end to end)."""
    mesh = make_sort_mesh(8)
    dead = list(mesh.devices.flat)[2].id
    inj = FaultInjector(run_round_segment, device_loss={0: dead},
                        device_return={3: dead})
    mon = DeviceHealthMonitor(lost_after=1, probe=inj.healthy)
    server = SortServer(HW, d=D, cfg=CFG, max_batch=8, autostart=False,
                        mesh=mesh, engine_fn=inj, device_health=mon,
                        brownout=BrownoutPolicy(),
                        retry=RetryPolicy(max_retries=2,
                                          backoff_base_s=0.0))
    xs = _problems(2, seed=19)
    futs = [server.submit(xs[i], key=jax.random.PRNGKey(40 + i))
            for i in range(2)]
    _drain(server)
    for f in futs:
        f.result(timeout=5)
    for _ in range(4):                       # idle ticks: ladder decays
        server._tick()
    server.close()
    assert any(e["event"] == "brownout_up" for e in server.events)
    assert server.stats["device_returns"] == 1
    assert server._brownout_level == 0       # capacity back -> full quality


# ------------------------------------- always-on subprocess coverage

def test_elastic_reshard_in_forced_8_device_subprocess():
    """Single-device hosts still prove the elastic path: a subprocess
    with 8 forced host devices re-runs (a) the rung-boundary re-shard
    bit-identity check and (b) the server-level eviction proof."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core.shufflesoftsort import (ShuffleSoftSortConfig,
            run_round_segment, shuffle_soft_sort, shuffle_soft_sort_batched)
        from repro.launch.mesh import make_sort_mesh
        from repro.launch.serve import SortServer
        from repro.runtime.fault_tolerance import FaultInjector, RetryPolicy
        from repro.runtime.straggler import DeviceHealthMonitor

        hw, n = (4, 4), 16
        cfg = ShuffleSoftSortConfig(rounds=3, inner_steps=2, chunk=16)
        xs = np.random.RandomState(0).rand(3, n, 2).astype(np.float32)
        keys = jax.random.split(jax.random.PRNGKey(1), 3 * 2)

        # (a) rung-boundary re-shard == uninterrupted run, bit for bit
        starts = []
        ref = shuffle_soft_sort_batched(
            xs, hw, cfg, n_restarts=2, keys=keys, mesh=make_sort_mesh(8),
            mesh_hook=lambda s, m: starts.append(s))
        evict_at = [s for s in starts if s > 0][0]
        def hook(start, mesh):
            if start != evict_at:
                return None
            surv = [d for d in mesh.devices.flat][:-1]
            return make_sort_mesh(len(surv), devices=surv)
        shd = shuffle_soft_sort_batched(
            xs, hw, cfg, n_restarts=2, keys=keys, mesh=make_sort_mesh(8),
            mesh_hook=hook)
        assert np.array_equal(ref.all_orders, shd.all_orders)
        assert np.array_equal(ref.all_losses, shd.all_losses)

        # (b) server eviction: one fault, one re-shard, exact results
        mesh = make_sort_mesh(8)
        dead = list(mesh.devices.flat)[3].id
        inj = FaultInjector(run_round_segment, device_loss={1: dead})
        mon = DeviceHealthMonitor(lost_after=1, probe=inj.healthy)
        server = SortServer(hw, d=2, cfg=cfg, max_batch=8,
                            autostart=False, mesh=mesh, engine_fn=inj,
                            device_health=mon,
                            retry=RetryPolicy(max_retries=2,
                                              backoff_base_s=0.0))
        import time
        futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
                for i in range(3)]
        for _ in range(200):
            with server._cv:
                idle = not server._pending and not server._active
            if idle:
                break
            server._tick(); time.sleep(0.001)
        res = [f.result(timeout=5) for f in futs]
        server.close()
        assert server.stats["evictions"] == 1, server.stats
        assert server.stats["reshards"] == 1, server.stats
        assert inj.device_faults == 1
        assert int(server.mesh.shape["data"]) == 7
        for i, (order, _, _) in enumerate(res):
            o_ref, _, _ = shuffle_soft_sort(xs[i], hw, cfg,
                                            key=jax.random.PRNGKey(i))
            assert np.array_equal(order, o_ref), i
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
