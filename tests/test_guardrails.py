"""Permutation-integrity guardrail suite (EXPERIMENTS.md §Robustness,
"Silent corruption").

Three layers, each deterministic:

* **Probe units** — feed `GuardrailMonitor.check_rung` hand-corrupted
  state and assert the RIGHT probe fires (typed `IntegrityViolation`
  with a structured incident record).
* **Engine wiring** — a guarded run (probes + full-rate shadow
  recompute) commits bit-identical results to an unguarded one on the
  sequential / batched / segment paths, and `AnnealSupervisor` repairs
  injected corruption by replaying from the last *verified* checkpoint
  (then by retiring the kernel tier when the corruption persists).
* **Chaos grid** — `FaultInjector` value-corruption modes (bit-flip /
  sign-flip / stale-buffer / NaN-splat) at exact dispatch indices,
  across the oracle / kernel / banded / bf16 serving paths: every
  injected corruption is detected by a probe, repaired through the
  retry + `DivergencePolicy` path, and the repaired run's result is
  bit-identical per seed to an uninjected run of the same config.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax

import repro.core.shufflesoftsort as sss
from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    run_round_segment,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.core.softsort import band_tail_bound
from repro.launch.serve import SortServer, WarmHandoff
from repro.runtime.fault_tolerance import (
    AnnealSupervisor,
    CorruptionSpec,
    DivergencePolicy,
    FaultInjector,
    RetryPolicy,
)
from repro.runtime.guardrails import (
    GuardrailMonitor,
    GuardrailPolicy,
    IntegrityViolation,
    expected_key_chain,
    measured_dropped_mass,
    shadow_sampled,
)

N, HW, D = 16, (4, 4), 3
FULL_SHADOW = GuardrailPolicy(mode="shadow", shadow_rate=1.0)
INVARIANTS = GuardrailPolicy(mode="invariants")
FAST_RETRY = RetryPolicy(max_retries=4, backoff_base_s=0.0)


def _cfg(**kw):
    return ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=N, **kw)


def _mon(policy=INVARIANTS, dtype="float32"):
    return GuardrailMonitor(policy, context="test", dtype=dtype)


def _problem(seed=0):
    rng = np.random.RandomState(seed)
    return rng.rand(N, D).astype(np.float32)


# ------------------------------------------------------ policy / sampling

def test_policy_validates_mode_and_rate():
    with pytest.raises(ValueError):
        GuardrailPolicy(mode="paranoid")
    with pytest.raises(ValueError):
        GuardrailPolicy(mode="shadow", shadow_rate=1.5)
    with pytest.raises(ValueError):
        GuardrailPolicy(mode="shadow", shadow_rate=-0.1)


def test_shadow_sampling_is_deterministic_and_rate_shaped():
    picks = [shadow_sampled(7, s, 0.5) for s in range(512)]
    assert picks == [shadow_sampled(7, s, 0.5) for s in range(512)]
    frac = sum(picks) / len(picks)
    assert 0.35 < frac < 0.65          # crc32 hash is roughly uniform
    assert not any(shadow_sampled(7, s, 0.0) for s in range(64))
    assert all(shadow_sampled(7, s, 1.0) for s in range(64))
    # different seeds sample different rungs
    other = [shadow_sampled(8, s, 0.5) for s in range(512)]
    assert other != picks


def test_monitor_off_mode_checks_nothing():
    mon = _mon(GuardrailPolicy(mode="off"))
    assert not mon.active
    mon.check_rung(start=0, orders=np.array([[0, 0, 0, 0]]), n=4)
    assert mon.rungs_checked == 0


# ------------------------------------------------------------ probe units

def _expect_probe(probe, fn):
    with pytest.raises(IntegrityViolation) as ei:
        fn()
    assert ei.value.probe == probe
    rec = ei.value.incident()
    assert rec["probe"] == probe and rec["context"] == "test"
    return ei.value


def test_permutation_probe():
    mon = _mon()
    bad = np.tile(np.arange(N, dtype=np.int32), (2, 1))
    bad[1, 3] = bad[1, 4]              # duplicate -> not bijective
    v = _expect_probe(
        "permutation",
        lambda: mon.check_rung(start=0, orders=bad, n=N))
    assert v.detail["instance"] == 1
    assert mon.incidents and mon.incidents[0]["probe"] == "permutation"


def test_loss_sign_probe():
    mon = _mon()
    seg = np.full((2, 3), 0.5, np.float32)
    seg[1, 0] = -0.2
    _expect_probe("loss_sign",
                  lambda: mon.check_rung(start=4, losses=seg, tau=0.7))


def test_loss_explosion_probe_uses_committed_ceiling():
    mon = _mon()
    mon.check_rung(start=0, losses=np.full((2, 1), 1.0, np.float32))
    exploded = np.array([[1.0], [1e5]], np.float32)
    v = _expect_probe(
        "loss_explosion",
        lambda: mon.check_rung(start=2, losses=exploded))
    assert v.round == 3                # start + offending row


def test_stale_losses_probe_catches_repeated_buffer():
    mon = _mon()
    seg = np.linspace(1.0, 0.5, 4, dtype=np.float32).reshape(2, 2)
    mon.check_rung(start=0, losses=seg)
    _expect_probe("stale_losses",
                  lambda: mon.check_rung(start=2, losses=seg.copy()))


def test_finite_probe_catches_nan_splat():
    mon = _mon()
    seg = np.full((2, 2), 0.5, np.float32)
    seg[1, 1] = np.nan
    v = _expect_probe("finite",
                      lambda: mon.check_rung(start=6, losses=seg))
    assert v.round == 7                # start + offending row


def test_key_chain_probe():
    keys_in = np.arange(4, dtype=np.uint32).reshape(2, 2)
    good = expected_key_chain(keys_in, 3)
    mon = _mon()
    mon.check_rung(start=0, keys_in=keys_in, keys_out=good, seg_len=3)
    corrupt = good.copy()
    corrupt[0, 0] ^= np.uint32(1 << 7)
    _expect_probe(
        "key_chain",
        lambda: _mon().check_rung(start=0, keys_in=keys_in,
                                  keys_out=corrupt, seg_len=3))


def test_shadow_loss_and_order_probes():
    losses = np.array([[0.5], [0.4]], np.float32)
    orders = np.arange(N, dtype=np.int32)[None]
    mon = _mon(FULL_SHADOW)
    mon.check_rung(start=0, losses=losses, orders=orders,
                   oracle_losses=losses.copy(), oracle_orders=orders.copy())
    _expect_probe(
        "shadow",
        lambda: _mon(FULL_SHADOW).check_rung(
            start=0, losses=losses, oracle_losses=losses * 1.5))
    flipped = orders.copy()
    flipped[0, :2] = flipped[0, :2][::-1]
    _expect_probe(
        "shadow",
        lambda: _mon(FULL_SHADOW).check_rung(
            start=0, orders=orders, oracle_orders=flipped))


def test_shadow_tolerance_is_per_dtype():
    pol = GuardrailPolicy(mode="shadow", shadow_rate=1.0)
    assert pol.shadow_tol("float32") == pol.tol_f32
    # bf16 rung-level drift (~0.13 measured) must pass the shadow gate
    # even though it exceeds the 2e-2 apply-level parity envelope.
    assert pol.tol("bfloat16") < 0.134 < pol.shadow_tol("bfloat16")
    losses = np.array([[1.0]], np.float32)
    drifted = losses * (1 + 0.134)
    _mon(FULL_SHADOW, dtype="bfloat16").check_rung(
        start=0, losses=losses, oracle_losses=drifted)
    _expect_probe(
        "shadow",
        lambda: _mon(FULL_SHADOW, dtype="float32").check_rung(
            start=0, losses=losses, oracle_losses=drifted))
    # bf16 never compares orders (ties legitimately differ); f32 does
    assert not _mon(dtype="bfloat16").compare_orders()
    assert _mon(dtype="float32").compare_orders()


def test_band_tail_audit_measured_mass_dominated_by_bound():
    rng = np.random.RandomState(3)
    w = np.sort(rng.randn(4, N).astype(np.float32) * 3.0, axis=1)[:, ::-1]
    for tau in (0.2, 0.5):
        bound = float(np.max(band_tail_bound(
            w, np.full(4, tau, np.float32), 4)))
        meas = measured_dropped_mass(w, tau, 4)
        assert meas <= bound * 1.05 + 1e-6
    # the probe path itself: clean keys verify, non-finite keys trip
    mon = _mon(FULL_SHADOW)
    mon.check_rung(start=0, ws=w, tau=0.5, band=4)
    bad = w.copy()
    bad[0, 0] = np.inf
    _expect_probe(
        "band_tail",
        lambda: _mon(FULL_SHADOW).check_rung(start=0, ws=bad, tau=0.5,
                                             band=4))


# ------------------------------------------------------- engine wiring

def test_batched_guarded_run_is_bit_identical():
    xs = np.stack([_problem(0), _problem(1)])
    key = jax.random.PRNGKey(5)
    cfg = _cfg()
    r0 = shuffle_soft_sort_batched(xs, HW, cfg, key=key)
    r1 = shuffle_soft_sort_batched(xs, HW, cfg, key=key,
                                   guardrail=FULL_SHADOW)
    np.testing.assert_array_equal(r0.all_orders, r1.all_orders)
    np.testing.assert_array_equal(r0.all_losses, r1.all_losses)


def test_sequential_guarded_run_is_bit_identical():
    x, key = _problem(), jax.random.PRNGKey(5)
    cfg = _cfg()
    o0, s0, l0 = shuffle_soft_sort(x, HW, cfg, key=key)
    o1, s1, l1 = shuffle_soft_sort(x, HW, cfg, key=key,
                                   guardrail=FULL_SHADOW)
    np.testing.assert_array_equal(o0, o1)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_segment_guarded_run_is_bit_identical():
    cfg = _cfg()
    orders = np.tile(np.arange(N, dtype=np.int32), (2, 1))
    keys = np.stack([np.asarray(jax.random.PRNGKey(i), np.uint32)
                     for i in (3, 4)])
    xs = np.stack([_problem(0), _problem(1)])
    norms = np.ones(2, np.float32)
    p = np.zeros(2, np.int64)
    out0 = run_round_segment(xs, orders, keys, norms, p, 2,
                             hw=HW, cfg=cfg)
    out1 = run_round_segment(xs, orders.copy(), keys.copy(), norms,
                             p.copy(), 2, hw=HW, cfg=cfg,
                             guardrail=FULL_SHADOW)
    for a, b in zip(out0, out1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class _SegmentCorruptor:
    """Wrap the module-level `_run_segments` driver: sign-flip one loss
    of selected calls (optionally only while the kernel tier is on), so
    the guardrail probes see engine-level silent corruption."""

    def __init__(self, inner, corrupt_calls=(), kernel_only=False):
        self.inner = inner
        self.corrupt_calls = set(corrupt_calls)
        self.kernel_only = kernel_only
        self.calls = 0
        self.corruptions = 0

    def __call__(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        orders, keys, losses = self.inner(*args, **kwargs)
        corrupt = (i in self.corrupt_calls
                   or (self.kernel_only and kwargs["cfg"].use_kernel))
        if corrupt:
            losses = np.asarray(losses, np.float32).copy()
            losses.reshape(-1)[0] *= -1.0
            self.corruptions += 1
        return orders, keys, losses


def test_supervisor_repairs_transient_corruption_by_verified_replay(
        tmp_path, monkeypatch):
    xs = np.stack([_problem(0)])
    key, cfg = jax.random.PRNGKey(9), _cfg()
    clean = shuffle_soft_sort_batched(xs, HW, cfg, key=key)
    chaos = _SegmentCorruptor(sss._run_segments, corrupt_calls={1})
    monkeypatch.setattr(sss, "_run_segments", chaos)
    sup = AnnealSupervisor(checkpoint_dir=str(tmp_path),
                           degrade=DivergencePolicy(integrity_retries=2))
    out = sup.run(xs, HW, cfg, key=key, checkpoint_every=1,
                  guardrail=INVARIANTS)
    assert chaos.corruptions == 1
    assert sup.stats["verified_replays"] == 1
    assert not sup.stats["fallbacks"]          # no config change needed
    assert [r["probe"] for r in sup.stats["integrity_incidents"]] \
        == ["loss_sign"]
    # repaired run is bit-identical to an uninjected clean run
    np.testing.assert_array_equal(out.all_orders, clean.all_orders)
    np.testing.assert_array_equal(out.all_losses, clean.all_losses)


def test_supervisor_retires_kernel_tier_on_persistent_corruption(
        tmp_path, monkeypatch):
    xs = np.stack([_problem(0)])
    key, cfg = jax.random.PRNGKey(9), _cfg(use_kernel=True)
    chaos = _SegmentCorruptor(sss._run_segments, kernel_only=True)
    monkeypatch.setattr(sss, "_run_segments", chaos)
    sup = AnnealSupervisor(checkpoint_dir=str(tmp_path),
                           degrade=DivergencePolicy(integrity_retries=1))
    out = sup.run(xs, HW, cfg, key=key, checkpoint_every=1,
                  guardrail=INVARIANTS)
    # one verified replay (still corrupt), then the ladder retired the
    # kernel tier and the oracle finished the run
    assert sup.stats["verified_replays"] == 1
    assert sup.stats["fallbacks"] == [
        "retired kernel tier -> pure-jnp oracle apply"]
    order = np.asarray(out.all_orders).reshape(-1, N)
    assert (np.sort(order, axis=1) == np.arange(N)).all()


# ------------------------------------------------ serving: chaos grid

def _serve_once(cfg, x, key, *, engine=None, guardrail=None,
                submit_guardrail=None, retry=None):
    server = SortServer(HW, d=D, cfg=cfg, max_wait_ms=0.0, sched_rungs=2,
                        engine_fn=engine, guardrail=guardrail,
                        retry=retry or FAST_RETRY)
    try:
        fut = server.submit(x, key=key, guardrail=submit_guardrail)
        out = fut.result(timeout=300)
    finally:
        stats = server.stats
        server.close()
    return out, stats


PATHS = {
    "oracle": {},
    "kernel": {"use_kernel": True},
    "banded": {"use_kernel": True, "band": 8},
    "bf16": {"use_kernel": True, "compute_dtype": "bfloat16"},
}
# Value-corruption taxonomy at dispatch index 1 (the second rung): the
# target choices route each mode to a distinct probe family.
CORRUPTIONS = {
    "bitflip": CorruptionSpec("bitflip", "orders", 5),    # permutation
    "signflip": CorruptionSpec("signflip", "losses", 1),  # loss_sign
    "stale": CorruptionSpec("stale", "losses"),           # stale_losses
    "nan": CorruptionSpec("nan", "losses", 2),            # finite
}
_BASELINES: dict[str, tuple] = {}


def _baseline(path, cfg, x, key):
    if path not in _BASELINES:
        _BASELINES[path] = _serve_once(cfg, x, key)[0]
    return _BASELINES[path]


@pytest.mark.parametrize("corruption", sorted(CORRUPTIONS))
@pytest.mark.parametrize("path", sorted(PATHS))
def test_chaos_grid_detects_repairs_and_restores_bit_identity(
        path, corruption):
    cfg = _cfg(**PATHS[path])
    x, key = _problem(), jax.random.PRNGKey(11)
    clean = _baseline(path, cfg, x, key)
    inj = FaultInjector(run_round_segment,
                        corrupt_calls={1: CORRUPTIONS[corruption]})
    out, stats = _serve_once(cfg, x, key, engine=inj,
                             guardrail=FULL_SHADOW)
    assert inj.corruptions == 1, "corruption was not injected"
    assert stats["integrity_violations"] >= 1, "corruption not detected"
    assert stats["integrity_incidents"][0]["probe"] is not None
    # transient SDC: the replay is clean, no config change is consumed,
    # and the repaired result is bit-identical to the uninjected run
    assert stats["self_heals"] == 0
    for a, b in zip(out, clean):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chaos_key_corruption_caught_by_key_chain_probe():
    cfg = _cfg()
    x, key = _problem(), jax.random.PRNGKey(11)
    clean = _baseline("oracle", cfg, x, key)
    inj = FaultInjector(run_round_segment,
                        corrupt_calls={1: CorruptionSpec("bitflip",
                                                         "keys", 0)})
    out, stats = _serve_once(cfg, x, key, engine=inj,
                             guardrail=FULL_SHADOW)
    assert stats["integrity_incidents"][0]["probe"] == "key_chain"
    for a, b in zip(out, clean):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_persistent_corruption_self_heals_to_oracle():
    cfg = _cfg(use_kernel=True)
    x, key = _problem(), jax.random.PRNGKey(11)
    # strike 1 (replay) and strike 2 (past heal_after=1) both corrupt;
    # the heal retires the kernel tier, later dispatches are clean
    inj = FaultInjector(
        run_round_segment,
        corrupt_calls={1: CorruptionSpec("signflip", "losses", 0),
                       2: CorruptionSpec("signflip", "losses", 0)})
    out, stats = _serve_once(cfg, x, key, engine=inj,
                             guardrail=FULL_SHADOW)
    assert stats["integrity_violations"] == 2
    assert stats["self_heals"] == 1
    order = np.asarray(out[0])
    assert (np.sort(order) == np.arange(N)).all()


def test_per_request_guardrail_override_and_opt_out():
    cfg = _cfg()
    x, key = _problem(), jax.random.PRNGKey(11)
    clean = _baseline("oracle", cfg, x, key)
    spec = CorruptionSpec("signflip", "losses", 1)
    # unguarded server, guarded REQUEST: detection still happens
    inj = FaultInjector(run_round_segment, corrupt_calls={1: spec})
    out, stats = _serve_once(cfg, x, key, engine=inj,
                             submit_guardrail=FULL_SHADOW)
    assert stats["integrity_violations"] == 1
    for a, b in zip(out, clean):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # guarded server, request opts OUT: the corruption commits silently
    # (negative control — detection is the guardrail, not an accident)
    inj2 = FaultInjector(run_round_segment, corrupt_calls={1: spec})
    out2, stats2 = _serve_once(
        cfg, x, key, engine=inj2, guardrail=FULL_SHADOW,
        submit_guardrail=GuardrailPolicy(mode="off"))
    assert stats2["integrity_violations"] == 0
    assert not np.array_equal(np.asarray(out2[2]), np.asarray(clean[2]))


def test_guardrail_type_validation():
    with pytest.raises(TypeError):
        SortServer(HW, d=D, cfg=_cfg(), guardrail="shadow",
                   autostart=False)
    server = SortServer(HW, d=D, cfg=_cfg(), autostart=False)
    with pytest.raises(TypeError):
        server.submit(_problem(), guardrail="invariants")
    server.close()


# --------------------------------- injector serialization + warm handoff

def test_injector_state_dict_roundtrip():
    inj = FaultInjector(lambda: (np.zeros(2), np.zeros(2), np.ones(3)),
                        fail_calls={5}, delay_calls={2: 0.25},
                        corrupt_calls={3: CorruptionSpec("nan", "losses")})
    inj()
    inj()
    state = inj.state_dict()
    import json
    json.dumps(state)                       # JSON-able, by contract
    fresh = FaultInjector(lambda: None)
    fresh.load_state_dict(state)
    assert fresh.calls == 2
    assert fresh.fail_calls == {5}
    assert fresh.delay_calls == {2: 0.25}
    assert fresh.corrupt_calls == {3: CorruptionSpec("nan", "losses")}
    assert fresh.state_dict() == state


def test_warm_handoff_preserves_injection_cursor(tmp_path):
    """A preempted chaos scenario resumes with its injection cursor
    intact: the corruption scheduled for dispatch 1 fires exactly once,
    in the successor, and the repaired result stays bit-identical."""
    cfg = _cfg()
    x, key = _problem(), jax.random.PRNGKey(11)
    clean = _baseline("oracle", cfg, x, key)
    spec = CorruptionSpec("signflip", "losses", 0)
    inj1 = FaultInjector(run_round_segment, corrupt_calls={1: spec})
    s1 = SortServer(HW, d=D, cfg=cfg, sched_rungs=2, engine_fn=inj1,
                    guardrail=FULL_SHADOW, retry=FAST_RETRY,
                    checkpoint_dir=str(tmp_path), autostart=False)
    s1.submit(x, key=key)
    s1._tick()                          # dispatch 0 (clean rung 0)
    handoff = s1.close(drain=False)
    assert isinstance(handoff, WarmHandoff)
    assert handoff.injector_state["calls"] == 1
    assert handoff.injector_state["corruptions"] == 0

    # successor in a "new process": fresh injector, cursor restored
    # from the persisted handoff
    inj2 = FaultInjector(run_round_segment, corrupt_calls={1: spec})
    s2 = SortServer(HW, d=D, cfg=cfg, sched_rungs=2, engine_fn=inj2,
                    guardrail=FULL_SHADOW, retry=FAST_RETRY,
                    resume=str(tmp_path), autostart=False)
    assert inj2.calls == 1              # cursor restored
    for _ in range(32):
        with s2._cv:
            if not s2._pending and not s2._active:
                break
        s2._tick()
    fut = s2.resumed[0].future
    out = fut.result(timeout=10)
    stats = s2.stats
    s2.close()
    assert inj2.corruptions == 1        # fired exactly once, post-resume
    assert stats["integrity_violations"] == 1
    for a, b in zip(out, clean):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------- CLI

def _cli(extra):
    from repro.launch.serve import main
    base = ["--workload", "sort", "--requests", "2", "--sort-n", "16",
            "--sort-hw", "4", "--sort-d", "2", "--rounds", "4",
            "--max-batch", "2"]
    return main(base + extra)


def test_cli_guardrail_smoke():
    out = _cli(["--guardrail", "shadow", "--shadow-rate", "1.0"])
    assert out["integrity_violations"] == 0     # clean run
    assert out["self_heals"] == 0
    assert out["improved"] >= 0


def test_cli_invariants_smoke():
    out = _cli(["--guardrail", "invariants"])
    assert out["integrity_violations"] == 0


def test_cli_shadow_rate_requires_shadow_mode(capsys):
    with pytest.raises(SystemExit):
        _cli(["--shadow-rate", "0.5"])
    assert "--guardrail shadow" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        _cli(["--guardrail", "invariants", "--shadow-rate", "0.5"])


def test_cli_shadow_rate_range_validated(capsys):
    with pytest.raises(SystemExit):
        _cli(["--guardrail", "shadow", "--shadow-rate", "1.5"])
    assert "must be in" in capsys.readouterr().err
