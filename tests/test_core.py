"""Unit + property tests for the permutation-learning core."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # degrade gracefully where absent
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.softsort import (
    softsort_matrix,
    softsort_apply_chunked,
    hard_permutation,
    is_valid_permutation,
    fix_permutation,
)
from repro.core.losses import (
    neighbor_loss_grid,
    stochastic_constraint_loss,
    std_loss,
    grid_sorting_loss,
    mean_pairwise_distance,
)
from repro.core.metrics import dpq, mean_neighbor_distance
from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    shuffle_soft_sort,
    soft_sort_baseline,
)


# ---------------------------------------------------------------- softsort

def test_softsort_matrix_rows_sum_to_one():
    w = jax.random.normal(jax.random.PRNGKey(0), (64,))
    p = softsort_matrix(w, tau=0.5)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), np.ones(64), rtol=1e-5)


def test_softsort_matrix_converges_to_argsort():
    w = jax.random.normal(jax.random.PRNGKey(1), (32,))
    p = softsort_matrix(w, tau=1e-4)
    hard = np.asarray(jnp.argmax(p, axis=-1))
    np.testing.assert_array_equal(hard, np.asarray(jnp.argsort(w)))


@pytest.mark.parametrize("n,chunk", [(64, 16), (128, 32), (96, 96), (32, 64)])
def test_chunked_apply_matches_dense(n, chunk):
    key = jax.random.PRNGKey(n)
    w = jax.random.normal(key, (n,))
    x = jax.random.normal(jax.random.PRNGKey(n + 1), (n, 5))
    p = softsort_matrix(w, tau=0.7)
    y_ref, cs_ref = p @ x, p.sum(0)
    y, cs = softsort_apply_chunked(w, x, tau=0.7, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(cs_ref), atol=1e-5)


def test_chunked_apply_gradients_match_dense():
    n = 64
    w = jax.random.normal(jax.random.PRNGKey(3), (n,))
    x = jax.random.normal(jax.random.PRNGKey(4), (n, 3))

    def loss_dense(w):
        p = softsort_matrix(w, 0.5)
        return jnp.sum((p @ x) ** 2) + jnp.sum(p.sum(0) ** 3)

    def loss_chunked(w):
        y, cs = softsort_apply_chunked(w, x, 0.5, chunk=16)
        return jnp.sum(y ** 2) + jnp.sum(cs ** 3)

    g1 = jax.grad(loss_dense)(w)
    g2 = jax.grad(loss_chunked)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_hard_permutation_is_argsort():
    w = jnp.array([3.0, 1.0, 2.0, -5.0])
    np.testing.assert_array_equal(np.asarray(hard_permutation(w)),
                                  [3, 1, 2, 0])


# --------------------------------------------------------- perm validity

@given(st.lists(st.integers(0, 19), min_size=20, max_size=20))
@settings(max_examples=50, deadline=None)
def test_fix_permutation_always_valid(idx):
    fixed = fix_permutation(np.array(idx))
    assert is_valid_permutation(fixed)


@given(st.permutations(list(range(12))))
@settings(max_examples=25, deadline=None)
def test_fix_permutation_identity_on_valid(perm):
    arr = np.array(perm)
    assert is_valid_permutation(arr)
    np.testing.assert_array_equal(fix_permutation(arr), arr)


# ------------------------------------------------------------------ losses

def test_neighbor_loss_zero_for_constant_grid():
    g = jnp.ones((4, 4, 3))
    assert float(neighbor_loss_grid(g)) < 1e-5


def test_stochastic_loss_zero_for_permutation():
    p = jnp.eye(16)[jnp.array(np.random.RandomState(0).permutation(16))]
    assert float(stochastic_constraint_loss(p.sum(0))) < 1e-9


def test_std_loss_zero_for_permutation():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 4))
    y = x[jnp.array(np.random.RandomState(1).permutation(32))]
    assert float(std_loss(x, y)) < 1e-6


def test_grid_sorting_loss_finite_grad():
    n, hw = 64, (8, 8)
    x = jax.random.uniform(jax.random.PRNGKey(0), (n, 3))
    norm = mean_pairwise_distance(x)

    def loss(w):
        y, cs = softsort_apply_chunked(w, x, 0.5, chunk=16)
        return grid_sorting_loss(y, cs, x, hw, norm)

    g = jax.grad(loss)(jnp.arange(n, dtype=jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))


# ----------------------------------------------------------------- metrics

def test_dpq_perfect_1d_ordering():
    # items whose features equal their grid coordinates: near-perfect layout
    h, w = 8, 8
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    x = np.stack([yy.ravel(), xx.ravel()], -1).astype(np.float64)
    assert dpq(x, (h, w)) > 0.9


def test_dpq_random_is_low():
    rng = np.random.RandomState(0)
    x = rng.rand(64, 8)
    assert dpq(x, (8, 8)) < 0.2


def test_mean_neighbor_distance_sorted_lt_random():
    rng = np.random.RandomState(0)
    x = np.sort(rng.rand(64))[:, None] * np.ones((1, 2))
    shuffled = x[rng.permutation(64)]
    assert mean_neighbor_distance(x, (8, 8)) < mean_neighbor_distance(
        shuffled, (8, 8))


# ------------------------------------------------- end-to-end (small N)

def test_shuffle_soft_sort_improves_layout_and_is_valid():
    n, hw = 64, (8, 8)
    x = jax.random.uniform(jax.random.PRNGKey(5), (n, 3))
    cfg = ShuffleSoftSortConfig(rounds=150, inner_steps=8, chunk=32)
    order, xs, losses = shuffle_soft_sort(x, hw, cfg, key=jax.random.PRNGKey(2))
    assert is_valid_permutation(order)
    base = mean_neighbor_distance(np.asarray(x), hw)
    assert mean_neighbor_distance(xs, hw) < 0.75 * base
    assert np.isfinite(losses).all()


def test_shuffle_beats_plain_softsort():
    n, hw = 64, (8, 8)
    x = jax.random.uniform(jax.random.PRNGKey(6), (n, 3))
    cfg = ShuffleSoftSortConfig(rounds=200, inner_steps=8, chunk=32)
    o1, xs1, _ = shuffle_soft_sort(x, hw, cfg, key=jax.random.PRNGKey(3))
    o2, xs2, _ = soft_sort_baseline(x, hw, cfg, steps=1600)
    assert dpq(xs1, hw) > dpq(xs2, hw)


def test_shuffle_soft_sort_deterministic_given_key():
    n, hw = 36, (6, 6)
    x = jax.random.uniform(jax.random.PRNGKey(9), (n, 2))
    cfg = ShuffleSoftSortConfig(rounds=20, inner_steps=4, chunk=36)
    o1, _, _ = shuffle_soft_sort(x, hw, cfg, key=jax.random.PRNGKey(1))
    o2, _, _ = shuffle_soft_sort(x, hw, cfg, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(o1, o2)


@given(st.integers(2, 5), st.integers(2, 5), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_shuffle_soft_sort_property_valid_any_shape(h, w, d):
    n = h * w
    x = jax.random.uniform(jax.random.PRNGKey(h * 31 + w), (n, d))
    cfg = ShuffleSoftSortConfig(rounds=5, inner_steps=2, chunk=n)
    order, _, _ = shuffle_soft_sort(x, (h, w), cfg)
    assert is_valid_permutation(order)
