"""Batched multi-problem / multi-restart engine tests.

Deliberately hypothesis-free so this module runs everywhere — it is the
primary coverage for the batched throughput path when the property-test
modules are skipped for a missing ``hypothesis``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.metrics import mean_neighbor_distance
from repro.core.softsort import is_valid_permutation, softsort_apply_chunked
from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.kernels.ops import softsort_apply
from repro.kernels.ref import softsort_apply_ref


# ------------------------------------------------- engine: bit-identity

def test_batched_bit_identical_to_sequential():
    """B x S = 8 instances must reproduce 8 sequential calls exactly."""
    b, s, n, hw = 4, 2, 36, (6, 6)
    cfg = ShuffleSoftSortConfig(rounds=6, inner_steps=4, chunk=36)
    xs = jax.random.uniform(jax.random.PRNGKey(42), (b, n, 2))
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(b * s)])

    res = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    assert res.all_orders.shape == (b, s, n)
    for bi in range(b):
        for si in range(s):
            o, xs_sorted, losses = shuffle_soft_sort(
                xs[bi], hw, cfg, key=keys[bi * s + si])
            np.testing.assert_array_equal(res.all_orders[bi, si], o)
            np.testing.assert_array_equal(res.all_losses[bi, si],
                                          np.asarray(losses))


def test_batched_streaming_callback_matches_scan_path():
    b, n, hw = 3, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=5, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, 3))
    fast = shuffle_soft_sort_batched(xs, hw, cfg, key=jax.random.PRNGKey(7))
    seen = []
    slow = shuffle_soft_sort_batched(xs, hw, cfg, key=jax.random.PRNGKey(7),
                                     callback=lambda r, o, l: seen.append(r))
    assert seen == list(range(cfg.rounds))
    np.testing.assert_array_equal(fast.all_orders, slow.all_orders)
    np.testing.assert_array_equal(fast.all_losses, slow.all_losses)


def test_batched_result_contract():
    """(order, sorted, losses) per problem + restart bookkeeping."""
    b, s, n, hw = 2, 3, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(1), (b, n, 2))
    res = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s,
                                    key=jax.random.PRNGKey(2))
    assert res.order.shape == (b, n)
    assert res.sorted.shape == (b, n, 2)
    assert res.losses.shape == (b, cfg.rounds)
    assert res.all_losses.shape == (b, s, cfg.rounds)
    for bi in range(b):
        assert is_valid_permutation(res.order[bi])
        for si in range(s):
            assert is_valid_permutation(res.all_orders[bi, si])
    # Best restart is the argmin of final losses, and the reported
    # per-problem fields are that restart's.
    np.testing.assert_array_equal(res.best_restart,
                                  np.argmin(res.all_losses[:, :, -1], axis=1))
    for bi in range(b):
        np.testing.assert_array_equal(
            res.order[bi], res.all_orders[bi, res.best_restart[bi]])
        np.testing.assert_array_equal(res.sorted[bi],
                                      np.asarray(xs[bi])[res.order[bi]])
        np.testing.assert_array_equal(
            res.losses[bi], res.all_losses[bi, res.best_restart[bi]])


def test_batched_improves_layouts():
    b, n, hw = 3, 64, (8, 8)
    cfg = ShuffleSoftSortConfig(rounds=100, inner_steps=8, chunk=32)
    xs = jax.random.uniform(jax.random.PRNGKey(5), (b, n, 3))
    res = shuffle_soft_sort_batched(xs, hw, cfg, key=jax.random.PRNGKey(2))
    for bi in range(b):
        base = mean_neighbor_distance(np.asarray(xs[bi]), hw)
        assert mean_neighbor_distance(res.sorted[bi], hw) < 0.8 * base


def test_batched_kernel_path_runs():
    b, n, hw = 2, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=2, inner_steps=2, use_kernel=True)
    xs = jax.random.uniform(jax.random.PRNGKey(3), (b, n, 2))
    res = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=2,
                                    key=jax.random.PRNGKey(4))
    for bi in range(b):
        for si in range(2):
            assert is_valid_permutation(res.all_orders[bi, si])
    assert np.isfinite(res.all_losses).all()


# ------------------------------------------- batched apply primitives

@pytest.mark.parametrize("n,d", [(64, 3), (100, 2), (300, 7)])
def test_batched_kernel_forward_matches_ref(n, d):
    b = 3
    w = jax.random.normal(jax.random.PRNGKey(n), (b, n)) * 2.0
    x = jax.random.normal(jax.random.PRNGKey(n + 1), (b, n, d))
    y, c = softsort_apply(w, x, 0.7)
    assert y.shape == (b, n, d) and c.shape == (b, n)
    for bi in range(b):
        yr, cr = softsort_apply_ref(w[bi], x[bi], 0.7)
        np.testing.assert_allclose(np.asarray(y[bi]), np.asarray(yr),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(c[bi]), np.asarray(cr),
                                   atol=2e-5)


def test_batched_kernel_gradients_match_ref():
    b, n, d = 2, 129, 5
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    w = jax.random.normal(keys[0], (b, n)) * 3
    x = jax.random.normal(keys[1], (b, n, d))
    a = jax.random.normal(keys[2], (b, n, d))
    v = jax.random.normal(keys[3], (b, n))

    def loss(apply_fn):
        def f(w, x, tau):
            y, c = apply_fn(w, x, tau)
            return jnp.sum(y * a) + jnp.sum(c * v)
        return f

    gk = jax.grad(loss(lambda w, x, t: softsort_apply(w, x, t, 256, 256, 64)),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    ref_b = jax.vmap(softsort_apply_ref, in_axes=(0, 0, None))
    gr = jax.grad(loss(ref_b), argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    for kk, rr in zip(gk, gr):
        scale = float(jnp.max(jnp.abs(rr))) + 1e-9
        np.testing.assert_allclose(np.asarray(kk), np.asarray(rr),
                                   atol=2e-3 * scale)


def test_unbatched_kernel_is_b1_special_case():
    n, d = 200, 4
    w = jax.random.normal(jax.random.PRNGKey(7), (n,)) * 10
    x = jax.random.normal(jax.random.PRNGKey(8), (n, d))
    y1, c1 = softsort_apply(w, x, 0.5)
    yb, cb = softsort_apply(w[None], x[None], 0.5)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(yb[0]))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(cb[0]))


@pytest.mark.parametrize("chunk", [16, 64])
def test_batched_chunked_apply_matches_per_instance(chunk):
    b, n, d = 3, 64, 5
    w = jax.random.normal(jax.random.PRNGKey(0), (b, n))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n, d))
    yb, cb = softsort_apply_chunked(w, x, 0.7, chunk=chunk)
    assert yb.shape == (b, n, d) and cb.shape == (b, n)
    for bi in range(b):
        y, c = softsort_apply_chunked(w[bi], x[bi], 0.7, chunk=chunk)
        np.testing.assert_allclose(np.asarray(yb[bi]), np.asarray(y),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(cb[bi]), np.asarray(c),
                                   atol=1e-6)


# --------------------------------------------------- sort serving queue

def test_sort_server_coalesces_and_matches_sequential():
    from repro.launch.serve import SortServer

    n, hw, d = 16, (4, 4), 2
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    server = SortServer(hw, d=d, cfg=cfg, max_batch=4, max_wait_ms=200.0)
    rng = np.random.RandomState(0)
    xs = rng.rand(4, n, d).astype(np.float32)
    try:
        futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
                for i in range(4)]
        results = [f.result(timeout=300) for f in futs]
    finally:
        server.close()

    # Coalesced: the continuous-batching scheduler dispatches one device
    # call per rung segment, with all 4 requests sharing each one — far
    # fewer dispatches than the 4 requests x 4 segments worst case.
    assert server.stats["requests"] == 4
    assert server.stats["batches"] < 4 * 4
    assert max(server.stats["batch_sizes"]) > 1
    for i, (order, xs_sorted, losses) in enumerate(results):
        o_ref, xs_ref, losses_ref = shuffle_soft_sort(
            xs[i], hw, cfg, key=jax.random.PRNGKey(i))
        np.testing.assert_array_equal(order, o_ref)
        np.testing.assert_array_equal(xs_sorted, xs_ref)
        np.testing.assert_array_equal(losses, np.asarray(losses_ref))
